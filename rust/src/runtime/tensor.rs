//! Host-side tensors: the plain row-major buffers that cross the backend
//! boundary. Backend-specific conversions (e.g. PJRT literals) live with
//! the backend that needs them.

/// Host-side tensor (f32, row-major) used at the runtime boundary.
#[derive(Clone, Debug, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl HostTensor {
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Self { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn scalar(v: f32) -> Self {
        Self { shape: vec![], data: vec![v] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Self { shape: shape.to_vec(), data }
    }
}

/// An i32 host tensor (hash matrices for predict_decode artifacts).
#[derive(Clone, Debug)]
pub struct HostTensorI32 {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_shapes() {
        let t = HostTensor::zeros(&[2, 3]);
        assert_eq!(t.data.len(), 6);
        let s = HostTensor::scalar(4.0);
        assert_eq!(s.shape, Vec::<usize>::new());
        assert_eq!(s.data, vec![4.0]);
    }

    #[test]
    fn from_vec_checks_length() {
        let t = HostTensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(t.shape, vec![2, 2]);
        assert_eq!(t.data[3], 4.0);
    }
}
