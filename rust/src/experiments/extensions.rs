//! Extension experiments beyond the paper's published evaluation —
//! the two concrete items its Sec. 7 leaves open:
//!
//! * `ext_fp`:      the pending "detailed, comparative analysis of false
//!                  positives and false negatives" — theory vs measured
//!                  FP rate across the (m/d, k) grid, FN rate (always 0),
//!                  and the phantom-item rate at the ranking level.
//! * `ext_counting`: counting Bloom embeddings — BE vs counting-BE score
//!                  ratios at the Table-3 test points.

use anyhow::Result;

use super::common::{fmt2, fmt3, Ctx, Table};
use crate::bloom::{measure_fp, HashMatrix};
use crate::coordinator::Method;
use crate::util::rng::Rng;
use crate::util::stats::mean;

/// FP/FN analysis across the compression grid (no training needed).
pub fn ext_fp(ctx: &Ctx) -> Result<Table> {
    let mut table = Table::new(
        "Ext. A — Bloom false-positive/negative analysis \
         (theory vs measured)",
        &["task", "m/d", "m", "k", "c", "fp theory", "fp measured",
          "fn", "phantom trials"]);
    let trials = 25;
    for task in ctx.tasks() {
        // measure at the task's median cardinality, over its m/d grid
        let c = task.c_median.max(1);
        for &ratio in &task.ratios {
            let m = crate::runtime::round_m(task.d, ratio);
            for k in [2usize, 4, 8] {
                if k > m {
                    continue;
                }
                let mut rng =
                    Rng::new(ctx.opts.seeds[0] ^ (m as u64) << 4 ^ k as u64);
                let hm = HashMatrix::random(task.d, m, k, &mut rng);
                let rep = measure_fp(&hm, c, trials, &mut rng);
                table.row(vec![
                    task.name.clone(),
                    fmt2(ratio),
                    m.to_string(),
                    k.to_string(),
                    c.to_string(),
                    format!("{:.2e}", rep.theory),
                    format!("{:.2e}", rep.observed_fp),
                    format!("{:.0e}", rep.observed_fn),
                    fmt2(rep.phantom_outrank),
                ]);
            }
        }
    }
    Ok(table)
}

/// Counting-BE vs binary BE at the Table-3 test points.
pub fn ext_counting(ctx: &Ctx) -> Result<Table> {
    let mut table = Table::new(
        "Ext. B — counting Bloom embeddings vs binary BE \
         (score ratios S_i/S_0, k=4)",
        &["task", "m/d", "BE", "counting BE", "delta"]);
    for task in ctx.tasks() {
        if task.family == "classifier" {
            continue; // outputs are classes; counting targets are moot
        }
        let s0 = ctx.s0(&task.name)?.max(1e-12);
        for &tp in &task.test_points {
            let be = mean(&ctx.score_over_seeds(
                &task.name, Method::Be { k: 4 }, tp)?) / s0;
            let cnt = mean(&ctx.score_over_seeds(
                &task.name, Method::CntBe { k: 4 }, tp)?) / s0;
            table.row(vec![
                task.name.clone(),
                fmt2(tp),
                fmt3(be),
                fmt3(cnt),
                format!("{:+.3}", cnt - be),
            ]);
        }
    }
    Ok(table)
}
