//! Extension experiments beyond the paper's published evaluation —
//! the two concrete items its Sec. 7 leaves open:
//!
//! * `ext_fp`:      the pending "detailed, comparative analysis of false
//!                  positives and false negatives" — theory vs measured
//!                  FP rate across the (m/d, k) grid, FN rate (always 0),
//!                  and the phantom-item rate at the ranking level.
//! * `ext_counting`: counting Bloom embeddings — BE vs counting-BE score
//!                  ratios at the Table-3 test points.
//! * `ext_quant`:   the quantized inference tier's accuracy cost —
//!                  ranking-metric deltas (MAP) of int8-panel + f16
//!                  serving vs the f32 oracle across the Bloom
//!                  compression grid, next to the payload-bytes win.

use anyhow::Result;

use super::common::{fmt2, fmt3, Ctx, Table};
use crate::bloom::{measure_fp, DecodeScratch, HashMatrix};
use crate::coordinator::batcher::{batch_ranges, encode_input_batch};
use crate::coordinator::{train_serving_model, Method};
use crate::data::{Dataset, Example, Target};
use crate::embedding::Embedding;
use crate::eval::average_precision_from_ranks;
use crate::linalg::knn::ranks_of;
use crate::model::ModelState;
use crate::runtime::{ArtifactSpec, Execution, QuantizedParams};
use crate::util::rng::Rng;
use crate::util::stats::mean;

/// FP/FN analysis across the compression grid (no training needed).
pub fn ext_fp(ctx: &Ctx) -> Result<Table> {
    let mut table = Table::new(
        "Ext. A — Bloom false-positive/negative analysis \
         (theory vs measured)",
        &["task", "m/d", "m", "k", "c", "fp theory", "fp measured",
          "fn", "phantom trials"]);
    let trials = 25;
    for task in ctx.tasks() {
        // measure at the task's median cardinality, over its m/d grid
        let c = task.c_median.max(1);
        for &ratio in &task.ratios {
            let m = crate::runtime::round_m(task.d, ratio);
            for k in [2usize, 4, 8] {
                if k > m {
                    continue;
                }
                let mut rng =
                    Rng::new(ctx.opts.seeds[0] ^ (m as u64) << 4 ^ k as u64);
                let hm = HashMatrix::random(task.d, m, k, &mut rng);
                let rep = measure_fp(&hm, c, trials, &mut rng);
                table.row(vec![
                    task.name.clone(),
                    fmt2(ratio),
                    m.to_string(),
                    k.to_string(),
                    c.to_string(),
                    format!("{:.2e}", rep.theory),
                    format!("{:.2e}", rep.observed_fp),
                    format!("{:.0e}", rep.observed_fn),
                    fmt2(rep.phantom_outrank),
                ]);
            }
        }
    }
    Ok(table)
}

/// MAP of a trained serving model over the test split, through either
/// the f32 predict (`quant = None`) or the quantized tier. Mirrors the
/// coordinator evaluator's MAP branch (exhaustive decode, consumed
/// inputs excluded, rank counting) so the two tiers are compared on
/// the paper's own measure.
fn map_over_test(exe: &dyn Execution, spec: &ArtifactSpec,
                 state: &ModelState, emb: &dyn Embedding, ds: &Dataset,
                 quant: Option<&QuantizedParams>) -> Result<f64> {
    let mut sum = 0.0f64;
    let mut n = 0usize;
    let mut scratch = DecodeScratch::new();
    let m = spec.m_out;
    for (lo, hi) in batch_ranges(ds.test.len(), spec.batch) {
        let batch: Vec<&Example> = ds.test[lo..hi].iter().collect();
        let x = encode_input_batch(spec, emb, &batch,
                                   exe.supports_sparse_input());
        let probs = match quant {
            Some(q) => exe.predict_quantized(q, &x)?,
            None => exe.predict(&state.params, &x)?,
        };
        for (row, ex) in batch.iter().enumerate() {
            let Target::Items(items) = &ex.target else { continue };
            let out_row = &probs.data[row * m..(row + 1) * m];
            emb.decode_into(out_row, &mut scratch);
            for &it in ex.input_items() {
                if (it as usize) < scratch.scores.len() {
                    scratch.scores[it as usize] = f32::NEG_INFINITY;
                }
            }
            let relevant: Vec<usize> =
                items.iter().map(|&i| i as usize).collect();
            let mut ranks = ranks_of(&scratch.scores, &relevant);
            sum += average_precision_from_ranks(&mut ranks);
            n += 1;
        }
    }
    Ok(sum / n.max(1) as f64)
}

/// Ext. C — the quantization axis over the compression grid: for each
/// FF recommender task and Table-3 Bloom ratio, one trained model
/// evaluated through both precision tiers. Reports the MAP delta the
/// int8+f16 tier costs and the weight-bytes reduction it buys.
pub fn ext_quant(ctx: &Ctx) -> Result<Table> {
    let mut table = Table::new(
        "Ext. C — quantized serving tier (int8 panels + f16 \
         activations) vs f32, MAP and payload bytes",
        &["task", "m/d", "MAP f32", "MAP int8", "delta",
          "bytes f32", "bytes int8", "ratio"]);
    for task in ctx.tasks() {
        if task.family != "ff" {
            continue; // the quantized tier covers the FF families only
        }
        for &ratio in &task.test_points {
            let sm = train_serving_model(
                ctx.rt, &ctx.data, &task.name, ratio, 4, ctx.opts.scale,
                ctx.opts.seeds[0], ctx.opts.epochs)?;
            let exe = ctx.rt.load_spec(&sm.spec)?;
            if !exe.supports_quantization() {
                continue;
            }
            let ds = ctx.data.get(&task, ctx.opts.scale,
                                  ctx.opts.seeds[0]);
            let q = exe.quantize_params(&sm.state.params)?;
            let map_f32 = map_over_test(exe.as_ref(), &sm.spec, &sm.state,
                                        sm.emb.as_ref(), &ds, None)?;
            let map_q8 = map_over_test(exe.as_ref(), &sm.spec, &sm.state,
                                       sm.emb.as_ref(), &ds, Some(&q))?;
            let bytes_f32: usize =
                sm.state.params.iter().map(|t| t.data.len() * 4).sum();
            let bytes_q8 = q.bytes();
            table.row(vec![
                task.name.clone(),
                fmt2(ratio),
                fmt3(map_f32),
                fmt3(map_q8),
                format!("{:+.4}", map_q8 - map_f32),
                bytes_f32.to_string(),
                bytes_q8.to_string(),
                fmt2(bytes_f32 as f64 / bytes_q8.max(1) as f64),
            ]);
        }
    }
    Ok(table)
}

/// Counting-BE vs binary BE at the Table-3 test points.
pub fn ext_counting(ctx: &Ctx) -> Result<Table> {
    let mut table = Table::new(
        "Ext. B — counting Bloom embeddings vs binary BE \
         (score ratios S_i/S_0, k=4)",
        &["task", "m/d", "BE", "counting BE", "delta"]);
    for task in ctx.tasks() {
        if task.family == "classifier" {
            continue; // outputs are classes; counting targets are moot
        }
        let s0 = ctx.s0(&task.name)?.max(1e-12);
        for &tp in &task.test_points {
            let be = mean(&ctx.score_over_seeds(
                &task.name, Method::Be { k: 4 }, tp)?) / s0;
            let cnt = mean(&ctx.score_over_seeds(
                &task.name, Method::CntBe { k: 4 }, tp)?) / s0;
            table.row(vec![
                task.name.clone(),
                fmt2(tp),
                fmt3(be),
                fmt3(cnt),
                format!("{:+.3}", cnt - be),
            ]);
        }
    }
    Ok(table)
}
