//! Table reproductions (paper Tables 1-5).

use anyhow::Result;

use super::common::{bold_best, fmt2, fmt3, Ctx, Table};
use crate::bloom::cooccurrence_stats;
use crate::coordinator::{random_score, Method};
use crate::eval::Measure;
use crate::util::stats::mean;

/// Table 1: dataset statistics after generation and splitting.
pub fn table1(ctx: &Ctx) -> Result<Table> {
    let mut table = Table::new(
        "Table 1 — dataset statistics (synthetic analogs)",
        &["dataset", "n", "split", "d", "c", "c/d"]);
    for task in ctx.tasks() {
        let ds = ctx.data.get(&task, ctx.opts.scale, ctx.opts.seeds[0]);
        let st = ds.stats();
        table.row(vec![
            task.name.clone(),
            st.n.to_string(),
            st.split.to_string(),
            st.d.to_string(),
            format!("{:.0}", st.c_median),
            format!("{:.1e}", st.density_median),
        ]);
    }
    Ok(table)
}

/// Table 2: setups + random score S_R + baseline score S_0.
pub fn table2(ctx: &Ctx) -> Result<Table> {
    let mut table = Table::new(
        "Table 2 — setups and baseline scores",
        &["dataset", "architecture", "optimizer", "measure", "S_R", "S_0"]);
    for task in ctx.tasks() {
        let ds = ctx.data.get(&task, ctx.opts.scale, ctx.opts.seeds[0]);
        let measure = Measure::parse(&task.metric).unwrap();
        let s_r = random_score(&ds, measure, ctx.opts.seeds[0]);
        let s0 = ctx.s0(&task.name)?;
        let arch = match task.family.as_str() {
            "ff" => format!("FF {:?}", task.hidden),
            "classifier" => format!("FF {:?}+{}", task.hidden,
                                    task.n_classes),
            other => format!("{} {:?}", other.to_uppercase(), task.hidden),
        };
        table.row(vec![
            task.name.clone(),
            arch,
            task.optimizer.clone(),
            measure.name().into(),
            fmt3(s_r),
            fmt3(s0),
        ]);
    }
    Ok(table)
}

/// Table 3: BE (k = 3, 4, 5) vs HT / ECOC / PMI / CCA at the two test
/// points per task; bold = best up to Mann-Whitney U significance.
pub fn table3(ctx: &Ctx) -> Result<Table> {
    let methods: Vec<(&str, Method)> = vec![
        ("HT", Method::Ht),
        ("ECOC", Method::Ecoc),
        ("PMI", Method::Pmi),
        ("CCA", Method::Cca),
        ("BE k=3", Method::Be { k: 3 }),
        ("BE k=4", Method::Be { k: 4 }),
        ("BE k=5", Method::Be { k: 5 }),
    ];
    let mut cols = vec!["dataset".to_string(), "m/d".to_string()];
    cols.extend(methods.iter().map(|(n, _)| n.to_string()));
    let mut table = Table::new(
        "Table 3 — BE vs alternatives (score ratios S_i/S_0)",
        &cols.iter().map(|s| s.as_str()).collect::<Vec<_>>());

    for task in ctx.tasks() {
        let s0 = ctx.s0(&task.name)?.max(1e-12);
        for &tp in &task.test_points {
            let mut samples: Vec<(String, Vec<f64>)> = Vec::new();
            for (label, method) in &methods {
                let scores =
                    ctx.score_over_seeds(&task.name, *method, tp)?;
                let ratios: Vec<f64> =
                    scores.iter().map(|s| s / s0).collect();
                samples.push((label.to_string(), ratios));
            }
            let cells = bold_best(&samples);
            let mut row = vec![task.name.clone(), fmt2(tp)];
            row.extend(cells.into_iter().map(|(_, c)| c));
            table.row(row);
        }
    }
    Ok(table)
}

/// Table 4: co-occurrence statistics + average CBE-over-BE score gain.
pub fn table4(ctx: &Ctx) -> Result<Table> {
    let mut table = Table::new(
        "Table 4 — co-occurrence statistics and CBE score increase",
        &["dataset", "in %", "in rho", "out %", "out rho",
          "gain k=3 (%)", "gain k=4 (%)"]);
    for task in ctx.tasks() {
        let ds = ctx.data.get(&task, ctx.opts.scale, ctx.opts.seeds[0]);
        let in_stats = cooccurrence_stats(&ds.train_input_csr());
        let (out_pct, out_rho) = if task.family == "classifier" {
            ("N/A".to_string(), "N/A".to_string())
        } else {
            let st = cooccurrence_stats(&ds.train_target_csr());
            (fmt2(st.pct_pairs), format!("{:.1e}", st.rho))
        };

        let s0 = ctx.s0(&task.name)?.max(1e-12);
        let mut gains = Vec::new();
        for k in [3usize, 4] {
            // paper: average of 100*(S_cbe - S_be)/S_0 over all m/d points
            let mut diffs = Vec::new();
            for &ratio in &task.ratios {
                let be = mean(&ctx.score_over_seeds(
                    &task.name, Method::Be { k }, ratio)?);
                let cbe = mean(&ctx.score_over_seeds(
                    &task.name, Method::Cbe { k }, ratio)?);
                diffs.push(100.0 * (cbe - be) / s0);
            }
            gains.push(mean(&diffs));
        }

        table.row(vec![
            task.name.clone(),
            fmt2(in_stats.pct_pairs),
            format!("{:.1e}", in_stats.rho),
            out_pct,
            out_rho,
            format!("{:+.1}", gains[0]),
            format!("{:+.1}", gains[1]),
        ]);
    }
    Ok(table)
}

/// Table 5: CBE (k = 3, 4) against the best method from Table 3 at each
/// test point.
pub fn table5(ctx: &Ctx) -> Result<Table> {
    let alternatives: Vec<(&str, Method)> = vec![
        ("HT", Method::Ht),
        ("ECOC", Method::Ecoc),
        ("PMI", Method::Pmi),
        ("CCA", Method::Cca),
        ("BE k=3", Method::Be { k: 3 }),
        ("BE k=4", Method::Be { k: 4 }),
        ("BE k=5", Method::Be { k: 5 }),
    ];
    let mut table = Table::new(
        "Table 5 — CBE vs best-so-far (score ratios S_i/S_0)",
        &["dataset", "m/d", "best method", "best", "CBE k=3", "CBE k=4"]);

    for task in ctx.tasks() {
        let s0 = ctx.s0(&task.name)?.max(1e-12);
        for &tp in &task.test_points {
            // best-so-far among Table 3's contenders
            let mut best: Option<(String, f64)> = None;
            for (label, method) in &alternatives {
                let si = mean(&ctx.score_over_seeds(
                    &task.name, *method, tp)?) / s0;
                if best.as_ref().map_or(true, |(_, b)| si > *b) {
                    best = Some((label.to_string(), si));
                }
            }
            let (best_label, best_score) = best.unwrap();
            let cbe3 = mean(&ctx.score_over_seeds(
                &task.name, Method::Cbe { k: 3 }, tp)?) / s0;
            let cbe4 = mean(&ctx.score_over_seeds(
                &task.name, Method::Cbe { k: 4 }, tp)?) / s0;
            table.row(vec![
                task.name.clone(),
                fmt2(tp),
                best_label,
                fmt3(best_score),
                fmt3(cbe3),
                fmt3(cbe4),
            ]);
        }
    }
    Ok(table)
}
