//! Shared experiment machinery: grid execution, S_0 baseline caching,
//! table rendering, TSV output.
//!
//! Grid sweeps are data-parallel: the per-seed runs of a grid point fan
//! out across the global worker pool ([`WorkerPool::global`],
//! `BLOOMREC_THREADS`) via `scope_map`, with results collected in seed
//! order — every run is deterministic in its `(task, method, ratio,
//! seed)` key, so the sweep's tables are identical for every thread
//! count. Results stay memoised under the same keys as the serial
//! sweep.

use std::collections::HashMap;
use std::io::Write;
use std::path::Path;
use std::sync::Mutex;

use anyhow::Result;

use crate::config::Options;
use crate::coordinator::{run, DatasetCache, Method, RunResult, RunSpec};
use crate::runtime::Runtime;
use crate::util::stats::mean;
use crate::util::threadpool::WorkerPool;

/// Execution context threaded through every experiment.
pub struct Ctx<'a> {
    pub rt: &'a Runtime,
    pub opts: &'a Options,
    pub data: DatasetCache,
    /// memoised results keyed by (task, method, ratio, seed)
    results: Mutex<HashMap<String, RunResult>>,
}

impl<'a> Ctx<'a> {
    pub fn new(rt: &'a Runtime, opts: &'a Options) -> Self {
        Self {
            rt,
            opts,
            data: DatasetCache::new(),
            results: Mutex::new(HashMap::new()),
        }
    }

    fn key(spec: &RunSpec) -> String {
        format!("{}|{}|{:.4}|{}|{:?}",
                spec.task, spec.method.name(), spec.ratio, spec.seed,
                spec.scale)
    }

    /// Run one point (memoised — baselines are shared across figures).
    pub fn point(&self, task: &str, method: Method, ratio: f64, seed: u64)
        -> Result<RunResult> {
        let spec = RunSpec {
            task: task.into(),
            method,
            ratio,
            seed,
            scale: self.opts.scale,
            epochs: self.opts.epochs,
        };
        let key = Self::key(&spec);
        if let Some(r) = self.results.lock().unwrap().get(&key) {
            return Ok(r.clone());
        }
        crate::info!("run {} {} m/d={:.3} seed={}", spec.task,
                     spec.method.name(), ratio, seed);
        let result = run(self.rt, &self.data, &spec)?;
        self.results.lock().unwrap().insert(key, result.clone());
        Ok(result)
    }

    /// Baseline score S_0 for a task (mean over the option seeds).
    pub fn s0(&self, task: &str) -> Result<f64> {
        Ok(mean(&self.score_over_seeds(task, Method::Baseline, 1.0)?))
    }

    /// Baseline result of the FIRST seed (timing reference T_0 in Fig. 3).
    pub fn baseline_run(&self, task: &str) -> Result<RunResult> {
        self.point(task, Method::Baseline, 1.0, self.opts.seeds[0])
    }

    /// `score` over all seeds for a grid point, the per-seed runs fanned
    /// across the global worker pool and collected in seed order
    /// (deterministic: each run depends only on its key).
    pub fn score_over_seeds(&self, task: &str, method: Method, ratio: f64)
        -> Result<Vec<f64>> {
        WorkerPool::global()
            .scope_map(&self.opts.seeds, |&s| {
                Ok(self.point(task, method, ratio, s)?.score)
            })
            .into_iter()
            .collect()
    }

    pub fn tasks(&self) -> Vec<crate::runtime::TaskSpec> {
        self.rt
            .manifest
            .tasks
            .iter()
            .filter(|t| {
                if !self.opts.task_enabled(&t.name) {
                    return false;
                }
                if !self.rt.supports_task(t) {
                    crate::info!(
                        "skipping task {}: the '{}' backend cannot run \
                         family '{}'",
                        t.name, self.rt.backend_name(), t.family);
                    return false;
                }
                true
            })
            .cloned()
            .collect()
    }
}

/// A rendered experiment artifact: a title, column headers and rows.
#[derive(Clone, Debug)]
pub struct Table {
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, columns: &[&str]) -> Table {
        Table {
            title: title.into(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.columns.len());
        self.rows.push(cells);
    }

    /// Aligned plain-text rendering (also valid Markdown).
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> =
            self.columns.iter().map(|c| c.len()).collect();
        for row in &self.rows {
            for (w, cell) in widths.iter_mut().zip(row) {
                *w = (*w).max(cell.len());
            }
        }
        let mut out = format!("## {}\n\n", self.title);
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for (cell, w) in cells.iter().zip(widths) {
                line.push_str(&format!(" {cell:w$} |"));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.columns, &widths));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{:-<width$}|", "", width = w + 2));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }

    /// Tab-separated dump for plotting tools.
    pub fn write_tsv(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "{}", self.columns.join("\t"))?;
        for row in &self.rows {
            writeln!(f, "{}", row.join("\t"))?;
        }
        Ok(())
    }
}

pub fn fmt3(v: f64) -> String {
    format!("{v:.3}")
}

pub fn fmt2(v: f64) -> String {
    format!("{v:.2}")
}

/// Mark the best value in a row of (label, samples) with significance:
/// values statistically indistinguishable from the max are all bold —
/// mirroring the paper's Table 3 convention (Mann-Whitney U, p > 0.05).
///
/// MWU has no power below n = 4 per side (its smallest attainable
/// two-sided p at 3 vs 3 is 0.1), so for fewer seeds we fall back to a
/// one-pooled-sigma overlap rule; EXPERIMENTS.md documents which rule a
/// table used.
pub fn bold_best(samples: &[(String, Vec<f64>)]) -> Vec<(String, String)> {
    let best_idx = samples
        .iter()
        .enumerate()
        .max_by(|a, b| {
            mean(&a.1 .1).partial_cmp(&mean(&b.1 .1)).unwrap()
        })
        .map(|(i, _)| i);
    let Some(bi) = best_idx else { return Vec::new() };
    let best = &samples[bi].1;
    let best_mean = mean(best);
    samples
        .iter()
        .enumerate()
        .map(|(i, (label, vals))| {
            let m = mean(vals);
            let is_best = if i == bi {
                true
            } else if vals.len() >= 4 && best.len() >= 4 {
                crate::util::stats::mann_whitney_u(vals, best).p_value
                    > 0.05
            } else {
                let sigma = crate::util::stats::std_dev(vals)
                    .max(crate::util::stats::std_dev(best));
                (best_mean - m) <= sigma
            };
            let cell = if is_best {
                format!("**{m:.3}**")
            } else {
                format!("{m:.3}")
            };
            (label.clone(), cell)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned_markdown() {
        let mut t = Table::new("Demo", &["a", "long_column"]);
        t.row(vec!["1".into(), "2".into()]);
        let s = t.render();
        assert!(s.contains("## Demo"));
        assert!(s.contains("| a | long_column |"));
        assert!(s.contains("| 1 | 2           |"));
    }

    #[test]
    fn tsv_round_trip() {
        let mut t = Table::new("x", &["c1", "c2"]);
        t.row(vec!["a".into(), "b".into()]);
        let p = std::env::temp_dir().join("bloomrec_tsv_test.tsv");
        t.write_tsv(&p).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text, "c1\tc2\na\tb\n");
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn bold_best_marks_ties() {
        let rows = vec![
            ("lo".to_string(), vec![0.1, 0.11, 0.09, 0.1, 0.12]),
            ("hi_a".to_string(), vec![0.9, 0.91, 0.89, 0.9, 0.88]),
            ("hi_b".to_string(), vec![0.9, 0.9, 0.9, 0.91, 0.89]),
        ];
        let cells = bold_best(&rows);
        assert!(!cells[0].1.starts_with("**"));
        assert!(cells[1].1.starts_with("**"));
        assert!(cells[2].1.starts_with("**"));
    }
}
