//! Figure reproductions (paper Sec. 5-6): score/time curves as TSV series
//! plus rendered tables.

use anyhow::Result;

use super::common::{fmt2, fmt3, Ctx, Table};
use crate::coordinator::Method;
use crate::util::stats::mean;

/// Figure 1: S_i/S_0 vs m/d at k = 4, one series per task.
pub fn fig1(ctx: &Ctx) -> Result<Table> {
    let mut table = Table::new(
        "Figure 1 — score ratio S_i/S_0 vs dimensionality ratio m/d (BE, k=4)",
        &["task", "m/d", "S_i", "S_0", "S_i/S_0"]);
    for task in ctx.tasks() {
        let s0 = ctx.s0(&task.name)?;
        for &ratio in &task.ratios {
            let scores =
                ctx.score_over_seeds(&task.name, Method::Be { k: 4 }, ratio)?;
            let si = mean(&scores);
            table.row(vec![
                task.name.clone(),
                fmt2(ratio),
                fmt3(si),
                fmt3(s0),
                fmt3(si / s0.max(1e-12)),
            ]);
        }
    }
    Ok(table)
}

/// Figure 2: S_i/S_0 vs the number of hash functions k, at m/d = 0.3
/// (left panel) and m/d = 1.0 (right panel).
pub fn fig2(ctx: &Ctx) -> Result<Table> {
    let ks = [1usize, 2, 3, 4, 5, 7, 10];
    let mut table = Table::new(
        "Figure 2 — score ratio S_i/S_0 vs number of hash functions k",
        &["task", "m/d", "k", "S_i/S_0"]);
    for task in ctx.tasks() {
        let s0 = ctx.s0(&task.name)?;
        for &ratio in &[0.3f64, 1.0] {
            // CADE's grid has no 0.3 by default; clamp to nearest ratio
            let ratio = nearest(&task.ratios, ratio);
            for &k in &ks {
                let method = if k == 1 { Method::Ht } else { Method::Be { k } };
                let scores =
                    ctx.score_over_seeds(&task.name, method, ratio)?;
                table.row(vec![
                    task.name.clone(),
                    fmt2(ratio),
                    k.to_string(),
                    fmt3(mean(&scores) / s0.max(1e-12)),
                ]);
            }
        }
    }
    Ok(table)
}

/// Figure 3: training-time and evaluation-time ratios T_i/T_0 vs m/d
/// (k = 4). Uses the first seed only — timing, not score, is the payload.
pub fn fig3(ctx: &Ctx) -> Result<Table> {
    let mut table = Table::new(
        "Figure 3 — time ratios T_i/T_0 vs m/d (BE, k=4)",
        &["task", "m/d", "train_s", "eval_s", "train_ratio", "eval_ratio"]);
    for task in ctx.tasks() {
        let base = ctx.baseline_run(&task.name)?;
        let t0_train = base.train.train_secs.max(1e-9);
        let t0_eval = base.eval.eval_secs.max(1e-9);
        for &ratio in &task.ratios {
            let r = ctx.point(&task.name, Method::Be { k: 4 }, ratio,
                              ctx.opts.seeds[0])?;
            table.row(vec![
                task.name.clone(),
                fmt2(ratio),
                fmt3(r.train.train_secs),
                fmt3(r.eval.eval_secs),
                fmt3(r.train.train_secs / t0_train),
                fmt3(r.eval.eval_secs / t0_eval),
            ]);
        }
    }
    Ok(table)
}

/// Figure 4: CBE vs BE score-ratio curves at k = 4.
pub fn fig4(ctx: &Ctx) -> Result<Table> {
    let mut table = Table::new(
        "Figure 4 — CBE vs BE score ratios (k=4)",
        &["task", "m/d", "BE", "CBE", "CBE-BE"]);
    for task in ctx.tasks() {
        let s0 = ctx.s0(&task.name)?.max(1e-12);
        for &ratio in &task.ratios {
            let be = mean(&ctx.score_over_seeds(
                &task.name, Method::Be { k: 4 }, ratio)?) / s0;
            let cbe = mean(&ctx.score_over_seeds(
                &task.name, Method::Cbe { k: 4 }, ratio)?) / s0;
            table.row(vec![
                task.name.clone(),
                fmt2(ratio),
                fmt3(be),
                fmt3(cbe),
                fmt3(cbe - be),
            ]);
        }
    }
    Ok(table)
}

fn nearest(grid: &[f64], target: f64) -> f64 {
    grid.iter()
        .copied()
        .min_by(|a, b| {
            (a - target).abs().partial_cmp(&(b - target).abs()).unwrap()
        })
        .unwrap_or(target)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nearest_picks_closest() {
        assert_eq!(nearest(&[0.1, 0.3, 1.0], 0.3), 0.3);
        assert_eq!(nearest(&[0.01, 0.03, 0.1], 0.3), 0.1);
        assert_eq!(nearest(&[], 0.5), 0.5);
    }
}
