//! Experiment registry: one entry per paper table/figure (DESIGN.md
//! "Experiment index"). Each regenerates its artifact as a rendered table
//! + a TSV in the results directory.

pub mod common;
pub mod extensions;
pub mod figures;
pub mod tables;

use anyhow::{bail, Result};

pub use common::{Ctx, Table};

/// The paper's own tables and figures.
pub const ALL: &[&str] = &[
    "table1", "table2", "fig1", "fig2", "fig3", "table3", "fig4",
    "table4", "table5",
];

/// Extension experiments from the paper's future-work section.
pub const EXTENDED: &[&str] = &["ext_fp", "ext_counting", "ext_quant"];

/// Run one experiment by id; writes `<out>/<id>.tsv` and returns the
/// rendered table.
pub fn run_experiment(id: &str, ctx: &Ctx) -> Result<Table> {
    let table = match id {
        "table1" => tables::table1(ctx)?,
        "table2" => tables::table2(ctx)?,
        "table3" => tables::table3(ctx)?,
        "table4" => tables::table4(ctx)?,
        "table5" => tables::table5(ctx)?,
        "fig1" => figures::fig1(ctx)?,
        "fig2" => figures::fig2(ctx)?,
        "fig3" => figures::fig3(ctx)?,
        "fig4" => figures::fig4(ctx)?,
        "ext_fp" => extensions::ext_fp(ctx)?,
        "ext_counting" => extensions::ext_counting(ctx)?,
        "ext_quant" => extensions::ext_quant(ctx)?,
        other => bail!(
            "unknown experiment '{other}' (try: {ALL:?} or {EXTENDED:?})"),
    };
    let path = ctx.opts.out_dir.join(format!("{id}.tsv"));
    table.write_tsv(&path)?;
    Ok(table)
}
