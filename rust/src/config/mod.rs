//! Experiment/CLI configuration (hand-rolled argument parsing — no clap
//! in the offline vendor set).

use std::path::PathBuf;

use anyhow::{anyhow, bail, Result};

use crate::bloom::DecodeStrategy;
use crate::data::Scale;
use crate::linalg::Precision;

/// Global options shared by CLI subcommands and the bench harness.
#[derive(Clone, Debug)]
pub struct Options {
    pub artifact_dir: PathBuf,
    pub out_dir: PathBuf,
    pub scale: Scale,
    pub seeds: Vec<u64>,
    /// override per-task epoch defaults
    pub epochs: Option<usize>,
    /// restrict experiments to these tasks
    pub tasks: Option<Vec<String>>,
    pub top_n: usize,
    /// serving decode route (`--decode exhaustive|pruned|pruned:P,C`);
    /// `None` defers to the embedding default (`BLOOMREC_DECODE`)
    pub decode: Option<DecodeStrategy>,
    /// serve from a packed model artifact directory (`--artifact DIR`,
    /// see `bloomrec pack`) instead of training at startup
    pub artifact: Option<PathBuf>,
    /// serving replica count override (`--replicas N`); `None` defers
    /// to `BLOOMREC_REPLICAS` / the `ServeConfig` default
    pub replicas: Option<usize>,
    /// run the Zipf load harness for this many seconds instead of the
    /// test-split replay (`serve --load SECS`)
    pub load: Option<f64>,
    /// closed-loop client threads for the load harness
    /// (`--concurrency N`)
    pub concurrency: usize,
    /// precision tier for `serve` and `pack` (`--precision f32|int8`);
    /// `None` defers to `BLOOMREC_PRECISION` / the f32 default
    pub precision: Option<Precision>,
    /// default per-request serving deadline in (fractional)
    /// milliseconds (`--deadline-ms MS`); `None` defers to
    /// `BLOOMREC_DEADLINE_MS` / no deadline
    pub deadline_ms: Option<f64>,
}

impl Default for Options {
    fn default() -> Self {
        Self {
            artifact_dir: PathBuf::from("artifacts"),
            out_dir: PathBuf::from("results"),
            scale: Scale::Small,
            seeds: vec![1, 2, 3],
            epochs: None,
            tasks: None,
            top_n: 10,
            decode: None,
            artifact: None,
            replicas: None,
            load: None,
            concurrency: 32,
            precision: None,
            deadline_ms: None,
        }
    }
}

impl Options {
    /// Parse `--key value` style flags; returns remaining positionals.
    pub fn parse(args: &[String]) -> Result<(Options, Vec<String>)> {
        let mut opts = Options::default();
        let mut positional = Vec::new();
        let mut it = args.iter().peekable();
        while let Some(arg) = it.next() {
            match arg.as_str() {
                "--artifacts" => {
                    opts.artifact_dir = PathBuf::from(req(&mut it, arg)?);
                }
                "--out" => {
                    opts.out_dir = PathBuf::from(req(&mut it, arg)?);
                }
                "--scale" => {
                    let v = req(&mut it, arg)?;
                    opts.scale = Scale::parse(&v)
                        .ok_or_else(|| anyhow!("bad --scale '{v}'"))?;
                }
                "--seeds" => {
                    let v = req(&mut it, arg)?;
                    opts.seeds = v
                        .split(',')
                        .map(|s| s.trim().parse::<u64>())
                        .collect::<Result<Vec<_>, _>>()
                        .map_err(|e| anyhow!("bad --seeds: {e}"))?;
                    if opts.seeds.is_empty() {
                        bail!("--seeds needs at least one seed");
                    }
                }
                "--epochs" => {
                    opts.epochs = Some(req(&mut it, arg)?.parse()
                        .map_err(|e| anyhow!("bad --epochs: {e}"))?);
                }
                "--tasks" => {
                    let v = req(&mut it, arg)?;
                    opts.tasks = Some(
                        v.split(',').map(|s| s.trim().to_string()).collect());
                }
                "--top-n" => {
                    opts.top_n = req(&mut it, arg)?.parse()
                        .map_err(|e| anyhow!("bad --top-n: {e}"))?;
                }
                "--decode" => {
                    let v = req(&mut it, arg)?;
                    opts.decode = Some(DecodeStrategy::parse(&v)
                        .ok_or_else(|| anyhow!(
                            "bad --decode '{v}' (want exhaustive, \
                             pruned, or pruned:P,C)"))?);
                }
                "--artifact" => {
                    opts.artifact = Some(PathBuf::from(req(&mut it, arg)?));
                }
                "--replicas" => {
                    let n: usize = req(&mut it, arg)?.parse()
                        .map_err(|e| anyhow!("bad --replicas: {e}"))?;
                    if n == 0 {
                        bail!("--replicas needs at least 1");
                    }
                    opts.replicas = Some(n);
                }
                "--load" => {
                    let secs: f64 = req(&mut it, arg)?.parse()
                        .map_err(|e| anyhow!("bad --load: {e}"))?;
                    if !(secs > 0.0) {
                        bail!("--load needs a positive duration (secs)");
                    }
                    opts.load = Some(secs);
                }
                "--concurrency" => {
                    let n: usize = req(&mut it, arg)?.parse()
                        .map_err(|e| anyhow!("bad --concurrency: {e}"))?;
                    if n == 0 {
                        bail!("--concurrency needs at least 1");
                    }
                    opts.concurrency = n;
                }
                "--deadline-ms" => {
                    let ms: f64 = req(&mut it, arg)?.parse()
                        .map_err(|e| anyhow!("bad --deadline-ms: {e}"))?;
                    if !(ms > 0.0) {
                        bail!("--deadline-ms needs a positive duration \
                               (milliseconds)");
                    }
                    opts.deadline_ms = Some(ms);
                }
                "--precision" => {
                    let v = req(&mut it, arg)?;
                    opts.precision = Some(Precision::parse(&v)
                        .ok_or_else(|| anyhow!(
                            "bad --precision '{v}' (want f32 or int8)"))?);
                }
                _ if arg.starts_with("--") => bail!("unknown flag {arg}"),
                _ => positional.push(arg.clone()),
            }
        }
        Ok((opts, positional))
    }

    pub fn task_enabled(&self, name: &str) -> bool {
        self.tasks
            .as_ref()
            .map(|ts| ts.iter().any(|t| t == name))
            .unwrap_or(true)
    }
}

fn req<'a, I: Iterator<Item = &'a String>>(
    it: &mut std::iter::Peekable<I>, flag: &str) -> Result<String> {
    it.next()
        .cloned()
        .ok_or_else(|| anyhow!("{flag} needs a value"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_flags_and_positionals() {
        let (o, pos) = Options::parse(&sv(&[
            "fig1", "--scale", "tiny", "--seeds", "7,8",
            "--tasks", "ml,bc", "--epochs", "2",
        ])).unwrap();
        assert_eq!(pos, vec!["fig1"]);
        assert_eq!(o.scale, Scale::Tiny);
        assert_eq!(o.seeds, vec![7, 8]);
        assert_eq!(o.epochs, Some(2));
        assert!(o.task_enabled("ml"));
        assert!(!o.task_enabled("yc"));
    }

    #[test]
    fn rejects_bad_flags() {
        assert!(Options::parse(&sv(&["--scale", "huge"])).is_err());
        assert!(Options::parse(&sv(&["--bogus"])).is_err());
        assert!(Options::parse(&sv(&["--seeds"])).is_err());
        assert!(Options::parse(&sv(&["--decode", "bogus"])).is_err());
    }

    #[test]
    fn parses_decode_strategies() {
        let (o, _) = Options::parse(&[]).unwrap();
        assert_eq!(o.decode, None);
        let (o, _) =
            Options::parse(&sv(&["--decode", "exhaustive"])).unwrap();
        assert_eq!(o.decode, Some(DecodeStrategy::Exhaustive));
        let (o, _) =
            Options::parse(&sv(&["--decode", "pruned:32,1024"])).unwrap();
        assert_eq!(o.decode, Some(DecodeStrategy::Pruned {
            top_positions: 32,
            max_candidates: 1024,
        }));
    }

    #[test]
    fn parses_artifact_path() {
        let (o, _) = Options::parse(&[]).unwrap();
        assert_eq!(o.artifact, None);
        let (o, pos) =
            Options::parse(&sv(&["serve", "ml", "--artifact", "out/ml_art"]))
                .unwrap();
        assert_eq!(pos, vec!["serve", "ml"]);
        assert_eq!(o.artifact, Some(PathBuf::from("out/ml_art")));
        assert!(Options::parse(&sv(&["--artifact"])).is_err());
    }

    #[test]
    fn parses_precision_tier() {
        let (o, _) = Options::parse(&[]).unwrap();
        assert_eq!(o.precision, None);
        let (o, _) =
            Options::parse(&sv(&["--precision", "int8"])).unwrap();
        assert_eq!(o.precision, Some(Precision::Int8));
        let (o, _) =
            Options::parse(&sv(&["--precision", "f32"])).unwrap();
        assert_eq!(o.precision, Some(Precision::F32));
        assert!(Options::parse(&sv(&["--precision", "int4"])).is_err());
        assert!(Options::parse(&sv(&["--precision"])).is_err());
    }

    #[test]
    fn parses_deadline_ms() {
        let (o, _) = Options::parse(&[]).unwrap();
        assert_eq!(o.deadline_ms, None);
        let (o, _) =
            Options::parse(&sv(&["--deadline-ms", "7.5"])).unwrap();
        assert_eq!(o.deadline_ms, Some(7.5));
        assert!(Options::parse(&sv(&["--deadline-ms", "0"])).is_err());
        assert!(Options::parse(&sv(&["--deadline-ms", "nan"])).is_err());
        assert!(Options::parse(&sv(&["--deadline-ms"])).is_err());
    }

    #[test]
    fn defaults_enable_all_tasks() {
        let (o, _) = Options::parse(&[]).unwrap();
        assert!(o.task_enabled("anything"));
        assert_eq!(o.seeds, vec![1, 2, 3]);
    }
}
