//! Single-run experiment pipeline: dataset -> embedding method -> train ->
//! evaluate, with wall-clock accounting. Every paper table/figure is a
//! loop over [`run`] with different (task, method, m/d, k, seed) points.

use std::collections::HashMap;
use std::sync::Mutex;

use anyhow::{anyhow, Result};

use super::evaluate::{evaluate, random_score, EvalReport};
use super::train::{train, TrainConfig, TrainReport};
use crate::baselines::{build_cca, build_ecoc, build_pmi, EcocConfig};
use crate::bloom::{cbe_rewrite, HashMatrix};
use crate::data::{generate, Dataset, Scale};
use crate::embedding::{Bloom, Embedding, Identity, LossKind};
use crate::eval::Measure;
use crate::runtime::{round_m, Runtime, TaskSpec};
use crate::util::rng::Rng;

/// The methods compared in the paper (Secs. 4.3, 5, 6).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Method {
    /// plain model, m = d (S_0)
    Baseline,
    /// Bloom embedding with k hash functions
    Be { k: usize },
    /// co-occurrence-based BE (Algorithm 1)
    Cbe { k: usize },
    /// counting Bloom embedding (paper Sec. 7 extension)
    CntBe { k: usize },
    /// hashing trick = BE with k = 1
    Ht,
    /// error-correcting output codes
    Ecoc,
    /// PMI + SVD + KNN
    Pmi,
    /// CCA + SVD + KNN
    Cca,
}

impl Method {
    pub fn name(self) -> String {
        match self {
            Method::Baseline => "baseline".into(),
            Method::Be { k } => format!("be_k{k}"),
            Method::Cbe { k } => format!("cbe_k{k}"),
            Method::CntBe { k } => format!("cnt_be_k{k}"),
            Method::Ht => "ht".into(),
            Method::Ecoc => "ecoc".into(),
            Method::Pmi => "pmi".into(),
            Method::Cca => "cca".into(),
        }
    }

    pub fn parse(s: &str) -> Option<Method> {
        if s == "baseline" {
            return Some(Method::Baseline);
        }
        if s == "ht" {
            return Some(Method::Ht);
        }
        if s == "ecoc" {
            return Some(Method::Ecoc);
        }
        if s == "pmi" {
            return Some(Method::Pmi);
        }
        if s == "cca" {
            return Some(Method::Cca);
        }
        if let Some(k) = s.strip_prefix("be_k") {
            return k.parse().ok().map(|k| Method::Be { k });
        }
        if let Some(k) = s.strip_prefix("cbe_k") {
            return k.parse().ok().map(|k| Method::Cbe { k });
        }
        if let Some(k) = s.strip_prefix("cnt_be_k") {
            return k.parse().ok().map(|k| Method::CntBe { k });
        }
        None
    }

    /// Which artifact loss family this method trains with on item tasks.
    pub fn loss(self) -> LossKind {
        match self {
            Method::Pmi | Method::Cca => LossKind::Cosine,
            _ => LossKind::SoftmaxCe,
        }
    }
}

#[derive(Clone, Debug)]
pub struct RunSpec {
    pub task: String,
    pub method: Method,
    /// m/d compression ratio (ignored for Baseline, forced to 1.0)
    pub ratio: f64,
    pub seed: u64,
    pub scale: Scale,
    /// override the task's default epoch count
    pub epochs: Option<usize>,
}

#[derive(Clone, Debug)]
pub struct RunResult {
    pub spec_name: String,
    pub method: String,
    pub task: String,
    pub ratio: f64,
    pub m: usize,
    pub d: usize,
    pub score: f64,
    pub random_score: f64,
    pub train: TrainReport,
    pub eval: EvalReport,
    pub n_weights: usize,
}

/// Dataset cache: experiments sweep many (method, m) points over the same
/// synthetic data; regeneration is deterministic but not free.
#[derive(Default)]
pub struct DatasetCache {
    map: Mutex<HashMap<(String, u64, u8), std::sync::Arc<Dataset>>>,
}

impl DatasetCache {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn get(&self, task: &TaskSpec, scale: Scale, seed: u64)
        -> std::sync::Arc<Dataset> {
        let key = (task.name.clone(), seed, scale.factor() as u8 * 10
            + (scale.factor().fract() > 0.0) as u8);
        if let Some(ds) = self.map.lock().unwrap().get(&key) {
            return std::sync::Arc::clone(ds);
        }
        let ds = std::sync::Arc::new(generate(
            &task.name, &task.generator, task.d, task.c_median,
            task.n_train, task.n_test, task.n_classes,
            if task.family == "gru" || task.family == "lstm" {
                10
            } else {
                0
            },
            scale, seed,
        ));
        self.map
            .lock()
            .unwrap()
            .insert(key, std::sync::Arc::clone(&ds));
        ds
    }
}

/// Build the embedding for a method on a dataset.
pub fn build_embedding(method: Method, ds: &Dataset, task: &TaskSpec,
                       m: usize, seed: u64) -> Result<Box<dyn Embedding>> {
    let d = task.d;
    let mut rng = Rng::new(seed ^ 0xE4B3_0001);
    let is_classifier = task.family == "classifier";
    Ok(match method {
        Method::Baseline => Box::new(Identity { d }),
        Method::Ht => {
            let hm_in = HashMatrix::random(d, m, 1, &mut rng);
            let hm_out = (!is_classifier)
                .then(|| HashMatrix::random(d, m, 1, &mut rng));
            Box::new(Bloom::new(hm_in, hm_out))
        }
        Method::Be { k } => {
            let k = k.min(m);
            let hm_in = HashMatrix::random(d, m, k, &mut rng);
            let hm_out = (!is_classifier)
                .then(|| HashMatrix::random(d, m, k, &mut rng));
            Box::new(Bloom::new(hm_in, hm_out))
        }
        Method::Cbe { k } => {
            let k = k.min(m);
            let mut hm_in = HashMatrix::random(d, m, k, &mut rng);
            let mut hm_out = (!is_classifier)
                .then(|| HashMatrix::random(d, m, k, &mut rng));
            if m > 2 * k {
                let x_in = ds.train_input_csr();
                cbe_rewrite(&mut hm_in, &x_in, &mut rng);
                if let Some(out) = hm_out.as_mut() {
                    let x_out = ds.train_target_csr();
                    cbe_rewrite(out, &x_out, &mut rng);
                }
            }
            Box::new(Bloom::new_tagged(hm_in, hm_out, "cbe"))
        }
        Method::CntBe { k } => {
            let k = k.min(m);
            let hm_in = HashMatrix::random(d, m, k, &mut rng);
            let hm_out = (!is_classifier)
                .then(|| HashMatrix::random(d, m, k, &mut rng));
            Box::new(crate::bloom::CountingBloom::new(hm_in, hm_out))
        }
        Method::Ecoc => {
            let cfg = EcocConfig::default();
            Box::new(build_ecoc(d, m, &cfg, &mut rng))
        }
        Method::Pmi => {
            let x = ds.train_input_csr();
            Box::new(build_pmi(&x, m, &mut rng))
        }
        Method::Cca => {
            let x = ds.train_input_csr();
            if is_classifier {
                // no item-space output view: fall back to input/input CCA
                Box::new(build_cca(&x, &x, m, &mut rng))
            } else {
                let y = ds.train_target_csr();
                Box::new(build_cca(&x, &y, m, &mut rng))
            }
        }
    })
}

/// Run one (task, method, ratio, seed) experiment point end-to-end.
pub fn run(rt: &Runtime, cache: &DatasetCache, spec: &RunSpec)
    -> Result<RunResult> {
    let task = rt.manifest.task(&spec.task)?.clone();
    let ratio = if spec.method == Method::Baseline { 1.0 } else { spec.ratio };
    let m = round_m(task.d, ratio);
    let ds = cache.get(&task, spec.scale, spec.seed);
    let measure = Measure::parse(&task.metric)
        .ok_or_else(|| anyhow!("bad metric {}", task.metric))?;

    let emb = build_embedding(spec.method, &ds, &task, m, spec.seed)?;
    // classifier tasks always train softmax-CE over the class head;
    // item tasks pick the loss family by method
    let loss = if task.family == "classifier" {
        LossKind::SoftmaxCe
    } else {
        spec.method.loss()
    };
    let train_spec =
        rt.manifest.find(&task.name, "train", loss.tag(), m)?.clone();
    let predict_spec =
        rt.manifest.find(&task.name, "predict", loss.tag(), m)?.clone();

    let epochs = spec.epochs.unwrap_or(task.epochs);
    let cfg = TrainConfig {
        epochs,
        seed: spec.seed,
        verbose: false,
        shards: 0,
    };
    let (state, train_report) =
        train(rt, &train_spec, &ds, emb.as_ref(), &cfg)?;
    let eval_report =
        evaluate(rt, &predict_spec, &state, &ds, emb.as_ref(), measure)?;
    let s_r = random_score(&ds, measure, spec.seed);

    Ok(RunResult {
        spec_name: train_spec.name.clone(),
        method: spec.method.name(),
        task: task.name.clone(),
        ratio,
        m,
        d: task.d,
        score: eval_report.score,
        random_score: s_r,
        train: train_report,
        eval: eval_report,
        n_weights: train_spec.n_weights(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_parse_round_trip() {
        for m in [Method::Baseline, Method::Be { k: 4 }, Method::Cbe { k: 3 },
                  Method::CntBe { k: 4 }, Method::Ht, Method::Ecoc,
                  Method::Pmi, Method::Cca] {
            assert_eq!(Method::parse(&m.name()), Some(m), "{:?}", m);
        }
        assert_eq!(Method::parse("nope"), None);
    }

    #[test]
    fn loss_family_by_method() {
        assert_eq!(Method::Pmi.loss(), LossKind::Cosine);
        assert_eq!(Method::Cca.loss(), LossKind::Cosine);
        assert_eq!(Method::Be { k: 4 }.loss(), LossKind::SoftmaxCe);
        assert_eq!(Method::Ecoc.loss(), LossKind::SoftmaxCe);
    }
}
