//! Minibatch assembly: encode examples through an [`Embedding`] into the
//! batch representation the backend consumes — sparse active-position
//! rows first (the paper's O(c*k) encoding, flat [`SparseBatch`] rows
//! for FF artifacts and per-timestep [`SparseSeqBatch`] steps for the
//! recurrent ones), dense zero-padded tensors only for dense-only
//! embeddings and backends without sparse support. Training targets get
//! the same treatment on the output side: [`encode_target_batch`]
//! produces [`BatchTarget::Sparse`] rows so the dense `[batch, m_out]`
//! tensor never materializes on sparse-aware backends.

use crate::data::{Example, Input, Target, PAD};
use crate::embedding::Embedding;
use crate::runtime::{ArtifactSpec, BatchInput, BatchTarget, HostTensor,
                     SparseBatch, SparseSeqBatch};

/// Encode example inputs sparse-first: per-row active embedded positions
/// when the backend consumes them (`sparse`, from
/// [`crate::runtime::Execution::supports_sparse_input`]) and the
/// embedding produces them (Bloom/HT/CBE, identity, code matrices); a
/// dense `x` tensor otherwise (dense-only backends, PMI/CCA tables).
/// Sequence artifacts get one sparse step per (row, timestep) — each the
/// Bloom bits of that step's single item, empty for left-padding. The
/// dense `[batch, m_in]` / `[batch, seq_len, m_in]` multi-hot is never
/// materialized on the sparse path.
pub fn encode_input_batch(spec: &ArtifactSpec, emb: &dyn Embedding,
                          examples: &[&Example], sparse: bool)
    -> BatchInput {
    if spec.seq_len > 0 {
        if sparse {
            if let Some(sb) =
                encode_sequence_rows_sparse(spec, emb, examples)
            {
                return BatchInput::SparseSeq(sb);
            }
        }
        let mut x = HostTensor::zeros(&spec.x_shape());
        encode_inputs(spec, emb, examples, &mut x);
        return BatchInput::Dense(x);
    }
    let rows: Vec<&[u32]> = examples
        .iter()
        .map(|ex| match &ex.input {
            Input::Items(v) => v.as_slice(),
            Input::Sequence(_) => panic!("ff artifact, sequence input"),
        })
        .collect();
    encode_item_rows(spec, emb, &rows, sparse)
}

/// Sparse sequence assembly: the O(c*k)-per-step path for recurrent
/// artifacts. Returns `None` when the embedding is dense-only (PMI/CCA
/// tables) so the caller falls back to the dense tensor.
fn encode_sequence_rows_sparse(spec: &ArtifactSpec, emb: &dyn Embedding,
                               examples: &[&Example])
    -> Option<SparseSeqBatch> {
    let mut sb = SparseSeqBatch::new(spec.m_in, spec.seq_len);
    let mut scratch: Vec<(u32, f32)> = Vec::new();
    for ex in examples {
        let seq = match &ex.input {
            Input::Sequence(s) => s,
            Input::Items(_) => panic!("sequence artifact, set input"),
        };
        debug_assert_eq!(seq.len(), spec.seq_len);
        for &item in seq {
            if item == PAD {
                sb.push_step(&[]);
                continue;
            }
            if !emb.encode_input_sparse(&[item], &mut scratch) {
                return None;
            }
            sb.push_step(&scratch);
        }
    }
    Some(sb)
}

/// Shared batch assembly over raw item rows (training examples and
/// serving requests both reduce to this): try the sparse path, fall back
/// to a dense tensor. Flat FF inputs only — sequence artifacts go
/// through [`encode_inputs`].
pub fn encode_item_rows(spec: &ArtifactSpec, emb: &dyn Embedding,
                        rows: &[&[u32]], sparse: bool) -> BatchInput {
    debug_assert_eq!(spec.seq_len, 0, "flat ff inputs only");
    if sparse {
        let mut sb = SparseBatch::new(spec.m_in);
        let mut scratch: Vec<(u32, f32)> = Vec::new();
        let mut sparse_ok = true;
        for items in rows {
            if !emb.encode_input_sparse(items, &mut scratch) {
                sparse_ok = false;
                break;
            }
            sb.push_row(&scratch);
        }
        if sparse_ok {
            return BatchInput::Sparse(sb);
        }
    }
    let m = spec.m_in;
    let mut x = HostTensor::zeros(&spec.x_shape());
    for (row, items) in rows.iter().enumerate() {
        emb.encode_input(items, &mut x.data[row * m..(row + 1) * m]);
    }
    BatchInput::Dense(x)
}

/// Encode a slice of examples (<= spec.batch) into the x tensor.
pub fn encode_inputs(spec: &ArtifactSpec, emb: &dyn Embedding,
                     examples: &[&Example], out: &mut HostTensor) {
    debug_assert_eq!(out.shape, spec.x_shape());
    out.data.fill(0.0);
    let m = spec.m_in;
    if spec.seq_len > 0 {
        let t = spec.seq_len;
        for (row, ex) in examples.iter().enumerate() {
            let seq = match &ex.input {
                Input::Sequence(s) => s,
                Input::Items(_) => panic!("sequence artifact, set input"),
            };
            debug_assert_eq!(seq.len(), t);
            for (step, &item) in seq.iter().enumerate() {
                if item == PAD {
                    continue;
                }
                let lo = (row * t + step) * m;
                emb.encode_input(&[item], &mut out.data[lo..lo + m]);
            }
        }
    } else {
        for (row, ex) in examples.iter().enumerate() {
            let items = match &ex.input {
                Input::Items(v) => v,
                Input::Sequence(_) => panic!("ff artifact, sequence input"),
            };
            let lo = row * m;
            emb.encode_input(items, &mut out.data[lo..lo + m]);
        }
    }
}

/// Encode targets sparse-first — the output-side mirror of
/// [`encode_input_batch`]: per-row active embedded positions when the
/// backend's losses consume them (`sparse`, from
/// [`crate::runtime::Execution::supports_sparse_input`]) and the
/// embedding produces them (Bloom/HT/CBE, identity, code matrices;
/// class labels are a single one-hot position). The dense
/// `[batch, m_out]` target tensor only materializes for dense-only
/// embeddings (PMI/CCA) or dense-only backends.
pub fn encode_target_batch(spec: &ArtifactSpec, emb: &dyn Embedding,
                           examples: &[&Example], sparse: bool)
    -> BatchTarget {
    if sparse {
        let mut sb = SparseBatch::new(spec.m_out);
        let mut scratch: Vec<(u32, f32)> = Vec::new();
        let mut sparse_ok = true;
        for ex in examples {
            match &ex.target {
                Target::Items(items) => {
                    if !emb.encode_target_sparse(items, &mut scratch) {
                        sparse_ok = false;
                        break;
                    }
                    sb.push_row(&scratch);
                }
                Target::Class(c) => {
                    sb.push_row(&[(*c as u32, 1.0)]);
                }
            }
        }
        if sparse_ok {
            return BatchTarget::Sparse(sb);
        }
    }
    let mut y = HostTensor::zeros(&spec.y_shape());
    encode_targets(spec, emb, examples, &mut y);
    BatchTarget::Dense(y)
}

/// Encode targets: item sets through the embedding; class labels one-hot.
pub fn encode_targets(spec: &ArtifactSpec, emb: &dyn Embedding,
                      examples: &[&Example], out: &mut HostTensor) {
    debug_assert_eq!(out.shape, spec.y_shape());
    out.data.fill(0.0);
    let m = spec.m_out;
    for (row, ex) in examples.iter().enumerate() {
        let lo = row * m;
        match &ex.target {
            Target::Items(items) => {
                emb.encode_target(items, &mut out.data[lo..lo + m]);
            }
            Target::Class(c) => {
                out.data[lo + *c as usize] = 1.0;
            }
        }
    }
}

/// Iterator over index batches of fixed size (the last one short).
///
/// This is the *minibatch* cut (one backend call per range); the
/// *intra-batch* data-parallel cut — micro-shards inside one call —
/// uses [`crate::util::threadpool::split_ranges`], shared by the
/// sharded `train_step`, the evaluation ranking sweep and the parallel
/// kernels so every layer partitions rows by the same deterministic
/// rule.
pub fn batch_ranges(n: usize, batch: usize) -> Vec<(usize, usize)> {
    let mut out = Vec::with_capacity(n.div_ceil(batch));
    let mut lo = 0;
    while lo < n {
        let hi = (lo + batch).min(n);
        out.push((lo, hi));
        lo = hi;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bloom::HashMatrix;
    use crate::embedding::{Bloom, Identity};
    use crate::runtime::TensorSpec;
    use crate::util::rng::Rng;

    fn ff_spec(m: usize, batch: usize) -> ArtifactSpec {
        ArtifactSpec {
            name: "t".into(), task: "t".into(), family: "ff".into(),
            kind: "train".into(), loss: "softmax_ce".into(),
            m_in: m, m_out: m, hidden: vec![8], batch, seq_len: 0,
            optimizer: "adam".into(), opt_params: Default::default(),
            ratio: 1.0, file: "t".into(),
            params: vec![TensorSpec { name: "w".into(), shape: vec![m, m] }],
            opt_slots: 2, decode_d: 0, decode_k: 0,
        }
    }

    fn seq_spec(m: usize, batch: usize, t: usize) -> ArtifactSpec {
        let mut s = ff_spec(m, batch);
        s.seq_len = t;
        s.family = "gru".into();
        s
    }

    #[test]
    fn ff_inputs_encode_rows_and_pad() {
        let spec = ff_spec(8, 4);
        let emb = Identity { d: 8 };
        let e1 = Example { input: Input::Items(vec![1, 3]),
                           target: Target::Items(vec![2]) };
        let e2 = Example { input: Input::Items(vec![7]),
                           target: Target::Items(vec![0]) };
        let mut x = HostTensor::zeros(&spec.x_shape());
        encode_inputs(&spec, &emb, &[&e1, &e2], &mut x);
        assert_eq!(x.data[1], 1.0);
        assert_eq!(x.data[3], 1.0);
        assert_eq!(x.data[8 + 7], 1.0);
        // rows 2..4 padded
        assert!(x.data[16..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn sequence_inputs_respect_pad_steps() {
        let spec = seq_spec(8, 2, 3);
        let emb = Identity { d: 8 };
        let e = Example {
            input: Input::Sequence(vec![PAD, 2, 5]),
            target: Target::Items(vec![1]),
        };
        let mut x = HostTensor::zeros(&spec.x_shape());
        encode_inputs(&spec, &emb, &[&e], &mut x);
        // step 0 all zero, step 1 item 2, step 2 item 5
        assert!(x.data[0..8].iter().all(|&v| v == 0.0));
        assert_eq!(x.data[8 + 2], 1.0);
        assert_eq!(x.data[16 + 5], 1.0);
    }

    #[test]
    fn class_targets_one_hot() {
        let mut spec = ff_spec(12, 2);
        spec.m_out = 12;
        let emb = Identity { d: 12 };
        let e = Example { input: Input::Items(vec![0]),
                          target: Target::Class(7) };
        let mut y = HostTensor::zeros(&spec.y_shape());
        encode_targets(&spec, &emb, &[&e], &mut y);
        assert_eq!(y.data[7], 1.0);
        assert_eq!(y.data.iter().sum::<f32>(), 1.0);
    }

    #[test]
    fn bloom_targets_have_k_bits_per_item() {
        let mut rng = Rng::new(1);
        let spec = ff_spec(16, 1);
        let emb = Bloom::new(HashMatrix::random(32, 16, 3, &mut rng), None);
        let e = Example { input: Input::Items(vec![4]),
                          target: Target::Items(vec![9]) };
        let mut y = HostTensor::zeros(&spec.y_shape());
        encode_targets(&spec, &emb, &[&e], &mut y);
        assert_eq!(y.data.iter().filter(|&&v| v > 0.0).count(), 3);
    }

    #[test]
    fn encode_input_batch_is_sparse_for_bloom() {
        let mut rng = Rng::new(4);
        let spec = ff_spec(16, 4);
        let emb = Bloom::new(HashMatrix::random(32, 16, 3, &mut rng), None);
        let e1 = Example { input: Input::Items(vec![1, 9]),
                           target: Target::Items(vec![2]) };
        let e2 = Example { input: Input::Items(vec![30]),
                           target: Target::Items(vec![0]) };
        let x = encode_input_batch(&spec, &emb, &[&e1, &e2], true);
        let BatchInput::Sparse(sb) = &x else {
            panic!("bloom encodes sparse");
        };
        assert_eq!(sb.rows(), 2);
        // the sparse rows densify to exactly what encode_inputs builds
        let mut dense = HostTensor::zeros(&spec.x_shape());
        encode_inputs(&spec, &emb, &[&e1, &e2], &mut dense);
        assert_eq!(sb.to_dense(spec.batch), dense);
    }

    #[test]
    fn encode_input_batch_is_sparse_for_sequences() {
        let mut rng = Rng::new(8);
        let spec = seq_spec(16, 3, 4);
        let emb = Bloom::new(HashMatrix::random(32, 16, 3, &mut rng), None);
        let e1 = Example { input: Input::Sequence(vec![PAD, 4, 9, 1]),
                           target: Target::Items(vec![2]) };
        let e2 = Example { input: Input::Sequence(vec![7, 7, 30, 12]),
                           target: Target::Items(vec![0]) };
        let x = encode_input_batch(&spec, &emb, &[&e1, &e2], true);
        let BatchInput::SparseSeq(sb) = &x else {
            panic!("bloom encodes sparse sequences");
        };
        assert_eq!(sb.rows(), 2);
        // the PAD step is empty, every real step carries <= k positions
        assert!(sb.step(0, 0).0.is_empty());
        assert!(!sb.step(0, 1).0.is_empty());
        // the sparse steps densify to exactly what encode_inputs builds
        let mut dense = HostTensor::zeros(&spec.x_shape());
        encode_inputs(&spec, &emb, &[&e1, &e2], &mut dense);
        assert_eq!(sb.to_dense(spec.batch), dense);
        // a dense-only backend short-circuits straight to dense
        let x = encode_input_batch(&spec, &emb, &[&e1], false);
        assert!(matches!(x, BatchInput::Dense(_)));
    }

    #[test]
    fn encode_input_batch_falls_back_dense_for_tables() {
        use crate::embedding::DenseTable;
        use crate::linalg::dense::Mat;
        use crate::linalg::knn::Metric;
        let spec = ff_spec(2, 2);
        let table = Mat::from_rows(vec![vec![1.0, 0.0], vec![0.5, 0.5]]);
        let dt = DenseTable::new(table, Metric::Cosine, "pmi");
        let e = Example { input: Input::Items(vec![0, 1]),
                          target: Target::Items(vec![0]) };
        let x = encode_input_batch(&spec, &dt, &[&e], true);
        assert!(matches!(x, BatchInput::Dense(_)));
        // a dense-only backend short-circuits straight to dense
        let emb = Identity { d: 2 };
        let x = encode_input_batch(&spec, &emb, &[&e], false);
        assert!(matches!(x, BatchInput::Dense(_)));
    }

    #[test]
    fn encode_target_batch_is_sparse_for_bloom_and_classes() {
        let mut rng = Rng::new(6);
        let spec = ff_spec(16, 3);
        let emb = Bloom::new(HashMatrix::random(32, 16, 3, &mut rng), None);
        let e1 = Example { input: Input::Items(vec![1]),
                           target: Target::Items(vec![9, 4]) };
        let e2 = Example { input: Input::Items(vec![2]),
                           target: Target::Class(7) };
        let y = encode_target_batch(&spec, &emb, &[&e1, &e2], true);
        let BatchTarget::Sparse(sb) = &y else {
            panic!("bloom targets encode sparse");
        };
        assert_eq!(sb.rows(), 2);
        // the class row is a single one-hot position
        assert_eq!(sb.row(1), (&[7u32][..], &[1.0f32][..]));
        // the sparse rows densify to exactly what encode_targets builds
        let mut dense = HostTensor::zeros(&spec.y_shape());
        encode_targets(&spec, &emb, &[&e1, &e2], &mut dense);
        assert_eq!(sb.to_dense(spec.batch), dense);
        // dense-only embeddings and backends fall back to dense tensors
        use crate::embedding::DenseTable;
        use crate::linalg::dense::Mat;
        use crate::linalg::knn::Metric;
        let mut spec2 = ff_spec(2, 1);
        spec2.m_out = 2;
        let table = Mat::from_rows(vec![vec![1.0, 0.0], vec![0.5, 0.5]]);
        let dt = DenseTable::new(table, Metric::Cosine, "pmi");
        let e = Example { input: Input::Items(vec![0]),
                          target: Target::Items(vec![1]) };
        assert!(matches!(encode_target_batch(&spec2, &dt, &[&e], true),
                         BatchTarget::Dense(_)));
        assert!(matches!(encode_target_batch(&spec, &emb, &[&e1], false),
                         BatchTarget::Dense(_)));
    }

    #[test]
    fn batch_ranges_cover_everything() {
        assert_eq!(batch_ranges(10, 4), vec![(0, 4), (4, 8), (8, 10)]);
        assert_eq!(batch_ranges(8, 4), vec![(0, 4), (4, 8)]);
        assert_eq!(batch_ranges(0, 4), Vec::<(usize, usize)>::new());
        assert_eq!(batch_ranges(3, 64), vec![(0, 3)]);
    }
}
