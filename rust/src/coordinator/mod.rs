//! L3 coordination: batching, the training loop over AOT artifacts,
//! ranking evaluation, and the experiment pipeline that every paper
//! table/figure harness drives.

pub mod batcher;
pub mod evaluate;
pub mod experiment;
pub mod train;

pub use evaluate::{evaluate, random_score, EvalReport};
pub use experiment::{build_embedding, run, DatasetCache, Method, RunResult,
                     RunSpec};
pub use train::{train, train_serving_model, ServingModel, TrainConfig,
                TrainReport};
