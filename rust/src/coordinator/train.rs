//! Training orchestration: drive the train-step execution over the
//! dataset — shuffle, encode, execute, thread state; record per-epoch loss
//! and wall-clock (the T_i of Fig. 3).
//!
//! The loop is model-family agnostic: FF minibatches reach the backend as
//! flat sparse rows, recurrent ones (GRU/LSTM) as sparse per-timestep
//! steps — see [`encode_input_batch`] — and both fall back to dense
//! tensors when the backend or embedding cannot produce sparse input.
//!
//! Training is data-parallel: every step passes
//! [`TrainConfig::shards`] to the backend's `train_step_sharded`, which
//! fans the minibatch's rows across the global worker pool
//! (`BLOOMREC_THREADS`). Sharding never changes the loss curve — the
//! backends guarantee bit-identical results for every shard and thread
//! count.

use std::sync::Arc;

use anyhow::{bail, Result};

use super::batcher::{batch_ranges, encode_input_batch,
                     encode_target_batch};
use super::experiment::{build_embedding, DatasetCache, Method};
use crate::data::{Dataset, Scale};
use crate::embedding::Embedding;
use crate::model::ModelState;
use crate::runtime::{round_m, ArtifactSpec, Execution, Runtime, TaskSpec};
use crate::util::rng::Rng;
use crate::util::Stopwatch;

#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub epochs: usize,
    pub seed: u64,
    /// log epoch losses at info level
    pub verbose: bool,
    /// micro-shards per minibatch, fanned across the global worker pool
    /// by sharding-aware backends (0 = auto-size from the pool). The
    /// loss trajectory is bit-identical for every value — sharding is
    /// an execution detail, see `Execution::train_step_sharded`.
    pub shards: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self { epochs: 3, seed: 0, verbose: false, shards: 0 }
    }
}

#[derive(Clone, Debug)]
pub struct TrainReport {
    pub epoch_losses: Vec<f64>,
    pub steps: usize,
    pub train_secs: f64,
    /// per-step losses of the first epoch (loss-curve logging)
    pub first_epoch_curve: Vec<f32>,
}

/// Train the artifact on the dataset's training split.
pub fn train(rt: &Runtime, spec: &ArtifactSpec, ds: &Dataset,
             emb: &dyn Embedding, cfg: &TrainConfig)
    -> Result<(ModelState, TrainReport)> {
    let exe = rt.load(&spec.name)?;
    let mut rng = Rng::new(cfg.seed ^ 0x7EA1_0001);
    let mut state = ModelState::init(spec, &mut rng);
    let mut report = TrainReport {
        epoch_losses: Vec::with_capacity(cfg.epochs),
        steps: 0,
        train_secs: 0.0,
        first_epoch_curve: Vec::new(),
    };

    let mut order: Vec<usize> = (0..ds.train.len()).collect();
    let watch = Stopwatch::new();

    for epoch in 0..cfg.epochs {
        rng.shuffle(&mut order);
        let mut epoch_loss = 0.0f64;
        let mut n_batches = 0usize;
        for (lo, hi) in batch_ranges(order.len(), spec.batch) {
            let batch: Vec<&crate::data::Example> =
                order[lo..hi].iter().map(|&i| &ds.train[i]).collect();
            // sparse active-position rows (inputs AND targets) when
            // both the backend and the embedding support them; dense
            // otherwise
            let sparse = exe.supports_sparse_input();
            let x = encode_input_batch(spec, emb, &batch, sparse);
            let y = encode_target_batch(spec, emb, &batch, sparse);
            let loss =
                exe.train_step_sharded(&mut state, &x, &y, cfg.shards)?;

            epoch_loss += loss as f64;
            n_batches += 1;
            report.steps += 1;
            if epoch == 0 {
                report.first_epoch_curve.push(loss);
            }
        }
        let avg = epoch_loss / n_batches.max(1) as f64;
        report.epoch_losses.push(avg);
        if cfg.verbose {
            crate::info!("epoch {epoch}: loss {avg:.4} ({n_batches} steps)");
        }
    }
    report.train_secs = watch.elapsed_secs();
    Ok((state, report))
}

/// A trained model plus everything the serving/packing paths need to
/// run it: the predict-kind [`ArtifactSpec`], the weights, and the
/// Bloom embedding whose hash matrices define the wire format.
pub struct ServingModel {
    pub task: TaskSpec,
    /// the predict-kind spec matching `state`
    pub spec: ArtifactSpec,
    pub state: ModelState,
    pub emb: Arc<dyn Embedding>,
}

/// Train one Bloom-embedded configuration end to end and return the
/// pieces `bloomrec serve` and `bloomrec pack` both need. Factors the
/// train-then-serve preamble out of the CLI so the two subcommands
/// produce byte-identical models for the same inputs.
#[allow(clippy::too_many_arguments)]
pub fn train_serving_model(rt: &Runtime, cache: &DatasetCache,
                           task_name: &str, ratio: f64, k: usize,
                           scale: Scale, seed: u64,
                           epochs: Option<usize>)
    -> Result<ServingModel> {
    let task = rt.manifest.task(task_name)?.clone();
    if !rt.supports_task(&task) {
        bail!("the '{}' backend cannot run family '{}'",
              rt.backend_name(), task.family);
    }
    if task.family == "classifier" {
        bail!("serving supports the recommender tasks (ff: ml/msd/amz/bc, \
               recurrent: yc/ptb), not the classifier");
    }

    let m = round_m(task.d, ratio);
    let ds = cache.get(&task, scale, seed);
    let emb: Arc<dyn Embedding> =
        build_embedding(Method::Be { k }, &ds, &task, m, seed)?.into();
    let train_spec =
        rt.manifest.find(&task.name, "train", "softmax_ce", m)?.clone();
    let predict_spec =
        rt.manifest.find(&task.name, "predict", "softmax_ce", m)?.clone();
    let cfg = TrainConfig {
        epochs: epochs.unwrap_or(task.epochs),
        seed,
        verbose: true,
        shards: 0, // auto-size micro-shards from the worker pool
    };
    crate::info!("training {} (m/d={ratio}, k={k}) on the {} backend...",
                 task.name, rt.backend_name());
    let (state, _) = train(rt, &train_spec, &ds, emb.as_ref(), &cfg)?;
    Ok(ServingModel { task, spec: predict_spec, state, emb })
}
