//! Evaluation: run the predict artifact over the test split, decode model
//! outputs back to the original d-dim item space through the embedding,
//! and compute the task measure (MAP / RR / Acc). Wall-clock is the
//! evaluation-time T_i of Fig. 3 (right) — it deliberately *includes* the
//! decode/mapping cost, which is the overhead the paper quantifies.
//!
//! Like training, evaluation is model-family agnostic: the same loop
//! scores the FF rankers (MAP), the GRU session model and the LSTM
//! next-word model (both RR over the decoded next-item scores), and the
//! classifier (Acc), with batches encoded sparse whenever the backend
//! accepts them.
//!
//! Both halves of a batch are data-parallel: the forward pass fans row
//! shards across the global worker pool inside the backend, and the
//! per-example decode + rank-count sweep fans the batch's examples
//! across the same pool here, reducing contributions back in example
//! order — the reported score is bit-identical to the serial sweep for
//! every thread count. Each worker reuses one decode scratch bundle
//! ([`crate::bloom::DecodeScratch`], via [`Embedding::decode_into`])
//! across its examples, and the log-sum gather itself rides the SIMD
//! tier — the sweep allocates nothing per example.
//!
//! Evaluation always runs the *exhaustive* decode: MAP and RR need the
//! full-catalog rank of the relevant items, which the candidate-pruned
//! serving tier does not produce (it returns a top-N). The pruned path
//! is exercised by the serving stack and its recall-vs-oracle tests.

use std::collections::HashSet;

use anyhow::Result;

use super::batcher::{batch_ranges, encode_input_batch};
use crate::bloom::DecodeScratch;
use crate::data::{Dataset, Example, Target};
use crate::embedding::Embedding;
use crate::eval::{accuracy_pct, average_precision,
                  average_precision_from_ranks, Measure};
use crate::linalg::knn::{rank_of, ranks_of};
use crate::model::ModelState;
use crate::runtime::{ArtifactSpec, Execution, Runtime};
use crate::util::rng::Rng;
use crate::util::threadpool::{split_ranges, WorkerPool};
use crate::util::Stopwatch;

#[derive(Clone, Debug)]
pub struct EvalReport {
    pub score: f64,
    pub eval_secs: f64,
    pub n_examples: usize,
}

/// One example's contribution to the batch measure, computed on a
/// worker of the parallel ranking sweep and reduced back in example
/// order (so the totals accumulate exactly as the serial loop did).
enum RowScore {
    /// classifier: (predicted, truth)
    Pred(u16, u16),
    /// ranking: the example's AP / RR contribution
    Partial(f64),
}

/// Evaluate `state` on the dataset's test split.
///
/// For MAP tasks the user's already-consumed input items are excluded
/// from the ranking (standard top-N protocol, cf. Wu et al. [49]).
pub fn evaluate(rt: &Runtime, spec: &ArtifactSpec, state: &ModelState,
                ds: &Dataset, emb: &dyn Embedding, measure: Measure)
    -> Result<EvalReport> {
    let exe = rt.load(&spec.name)?;
    let watch = Stopwatch::new();
    let mut scores_sum = 0.0f64;
    let mut n = 0usize;
    let mut preds: Vec<u16> = Vec::new();
    let mut truths: Vec<u16> = Vec::new();

    let pool = WorkerPool::global();
    for (lo, hi) in batch_ranges(ds.test.len(), spec.batch) {
        let batch: Vec<&Example> = ds.test[lo..hi].iter().collect();
        let x = encode_input_batch(spec, emb, &batch,
                                   exe.supports_sparse_input());
        let probs = exe.predict(&state.params, &x)?; // [batch, m_out]
        let m = spec.m_out;

        // the ranking sweep — decode to the d-dim item space and
        // rank-count, the evaluation-time cost the paper quantifies —
        // fans the batch's examples across the pool in shard ranges and
        // reduces contributions back in example order (deterministic:
        // same totals as the serial loop, for every thread count).
        // Classifier accuracy is one argmax per example — far below the
        // cost of a fork-join — so it stays serial, as do tiny batches;
        // the decode-heavy Map/Rr sweep is what fans out.
        let workers = match measure {
            Measure::Acc => 1,
            _ if batch.len() < 8 => 1,
            _ => pool.threads(),
        };
        let ranges = split_ranges(batch.len(), workers);
        let parts = pool.scope_map(&ranges, |&(rlo, rhi)| {
            // per-worker decode scratch, reused across every example
            // of the range — the sweep allocates nothing per example
            let mut scratch = DecodeScratch::new();
            let mut out = Vec::with_capacity(rhi - rlo);
            for row in rlo..rhi {
                let ex = batch[row];
                let out_row = &probs.data[row * m..(row + 1) * m];
                match (&ex.target, measure) {
                    (Target::Class(c), Measure::Acc) => {
                        out.push(RowScore::Pred(argmax(out_row) as u16,
                                                *c));
                    }
                    (Target::Items(items), Measure::Map) => {
                        // rank-counting instead of a full argsort:
                        // O(d * r) (EXPERIMENTS.md §Perf, ~4x faster
                        // evaluation)
                        emb.decode_into(out_row, &mut scratch);
                        let scores = &mut scratch.scores;
                        for &it in ex.input_items() {
                            if (it as usize) < scores.len() {
                                scores[it as usize] = f32::NEG_INFINITY;
                            }
                        }
                        let relevant: Vec<usize> =
                            items.iter().map(|&i| i as usize).collect();
                        let mut ranks = ranks_of(scores, &relevant);
                        out.push(RowScore::Partial(
                            average_precision_from_ranks(&mut ranks)));
                    }
                    (Target::Items(items), Measure::Rr) => {
                        emb.decode_into(out_row, &mut scratch);
                        let rank = rank_of(&scratch.scores,
                                           items[0] as usize);
                        out.push(RowScore::Partial(1.0 / rank as f64));
                    }
                    _ => anyhow::bail!("measure/target mismatch"),
                }
            }
            Ok(out)
        });
        for part in parts {
            for score in part? {
                match score {
                    RowScore::Pred(pred, truth) => {
                        preds.push(pred);
                        truths.push(truth);
                    }
                    RowScore::Partial(s) => {
                        scores_sum += s;
                        n += 1;
                    }
                }
            }
        }
    }

    let score = match measure {
        Measure::Acc => accuracy_pct(&preds, &truths),
        _ => scores_sum / n.max(1) as f64,
    };
    Ok(EvalReport {
        score,
        eval_secs: watch.elapsed_secs(),
        n_examples: ds.test.len(),
    })
}

/// The paper's random reference score S_R (Table 2): the same measure
/// computed over uniformly random rankings/labels.
pub fn random_score(ds: &Dataset, measure: Measure, seed: u64) -> f64 {
    let mut rng = Rng::new(seed ^ 0x5EED_0BAD);
    let mut acc = 0.0f64;
    let mut n = 0usize;
    let mut correct = 0usize;
    for ex in &ds.test {
        match (&ex.target, measure) {
            (Target::Class(c), Measure::Acc) => {
                if rng.below(ds.n_classes.max(1)) == *c as usize {
                    correct += 1;
                }
                n += 1;
            }
            (Target::Items(items), Measure::Map) => {
                let mut ranking: Vec<usize> = (0..ds.d).collect();
                rng.shuffle(&mut ranking);
                let relevant: HashSet<usize> =
                    items.iter().map(|&i| i as usize).collect();
                acc += average_precision(&ranking, &relevant);
                n += 1;
            }
            (Target::Items(items), Measure::Rr) => {
                // expected RR of a uniform ranking ~ H(d)/d; sample it
                let pos = rng.below(ds.d);
                let _ = items;
                acc += 1.0 / (pos + 1) as f64;
                n += 1;
            }
            _ => {}
        }
    }
    match measure {
        Measure::Acc => 100.0 * correct as f64 / n.max(1) as f64,
        _ => acc / n.max(1) as f64,
    }
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Input, Scale};

    #[test]
    fn argmax_basics() {
        assert_eq!(argmax(&[0.1, 0.9, 0.5]), 1);
        assert_eq!(argmax(&[1.0]), 0);
        assert_eq!(argmax(&[2.0, 2.0]), 0);
    }

    #[test]
    fn random_score_is_small_for_ranking_tasks() {
        let ds = crate::data::generate("t", "profiles_sparse", 1024, 4,
                                       200, 200, 0, 0, Scale::Tiny, 3);
        let s = random_score(&ds, Measure::Map, 1);
        assert!(s < 0.05, "random MAP {s} too high");
        let s = random_score(&ds, Measure::Rr, 1);
        assert!(s < 0.05, "random RR {s} too high");
    }

    #[test]
    fn random_score_for_classes_near_uniform() {
        let ds = crate::data::generate("t", "topic_docs", 512, 8, 400, 400,
                                       12, 0, Scale::Tiny, 4);
        // tiny scale leaves ~50 test docs: binomial noise is large, so
        // only bound the score loosely around the 1/12 ~ 8.3% uniform rate
        let s = random_score(&ds, Measure::Acc, 1);
        assert!(s > 1.0 && s < 22.0, "random acc {s}");
    }

    #[test]
    fn random_rr_uses_positions_not_items() {
        let ds = Dataset {
            name: "x".into(), d: 100, n_classes: 0, seq_len: 2,
            train: vec![],
            test: (0..50).map(|i| Example {
                input: Input::Sequence(vec![i % 100, (i + 1) % 100]),
                target: Target::Items(vec![i % 100]),
            }).collect(),
        };
        let s = random_score(&ds, Measure::Rr, 2);
        assert!(s > 0.0 && s < 0.3, "{s}");
    }
}
