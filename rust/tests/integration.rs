//! Integration tests over the full stack: runtime backend + coordinator.
//! With AOT artifacts built (`make artifacts`) and the `xla` feature these
//! exercise the PJRT path; otherwise they run end-to-end on the native
//! backend over the synthetic manifest, so plain `cargo test` covers the
//! whole pipeline — all seven tasks, recurrent families included — in a
//! fresh checkout.

use std::path::{Path, PathBuf};
use std::sync::OnceLock;

use bloomrec::coordinator::{self, DatasetCache, Method, RunSpec};
use bloomrec::data::Scale;
use bloomrec::eval::Measure;
use bloomrec::runtime::{Execution, Runtime};

fn artifact_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn runtime() -> Option<&'static Runtime> {
    static RT: OnceLock<Option<Runtime>> = OnceLock::new();
    RT.get_or_init(|| {
        let rt = Runtime::new(&artifact_dir()).expect("runtime");
        eprintln!("integration tests on the '{}' backend",
                  rt.backend_name());
        Some(rt)
    })
    .as_ref()
}

fn cache() -> &'static DatasetCache {
    static C: OnceLock<DatasetCache> = OnceLock::new();
    C.get_or_init(DatasetCache::new)
}

#[test]
fn manifest_covers_all_seven_tasks() {
    let Some(rt) = runtime() else { return };
    assert_eq!(rt.manifest.tasks.len(), 7);
    for t in &rt.manifest.tasks {
        for &tp in &t.test_points {
            let m = bloomrec::runtime::round_m(t.d, tp);
            assert!(rt.manifest.find(&t.name, "train", "softmax_ce", m)
                .is_ok(), "{}@{tp}", t.name);
            assert!(rt.manifest.find(&t.name, "predict", "softmax_ce", m)
                .is_ok(), "{}@{tp}", t.name);
        }
    }
}

#[test]
fn train_step_reduces_loss_ff() {
    let Some(rt) = runtime() else { return };
    let spec = RunSpec {
        task: "bc".into(),
        method: Method::Be { k: 4 },
        ratio: 0.3,
        seed: 7,
        scale: Scale::Tiny,
        epochs: Some(4),
    };
    let res = coordinator::run(rt, cache(), &spec).expect("run");
    let first = res.train.epoch_losses[0];
    let last = *res.train.epoch_losses.last().unwrap();
    assert!(last < first,
            "loss did not decrease: {:?}", res.train.epoch_losses);
    assert!(res.score > res.random_score,
            "score {} <= random {}", res.score, res.random_score);
}

#[test]
fn train_step_reduces_loss_recurrent() {
    let Some(rt) = runtime() else { return };
    // yc (GRU + adagrad) and ptb (LSTM + sgd/momentum/clip) now run on
    // every backend, the native interpreter included — no skip branch
    for task in ["yc", "ptb"] {
        let spec_task = rt.manifest.task(task).expect(task);
        assert!(rt.supports_task(spec_task),
                "backend '{}' must support family '{}'",
                rt.backend_name(), spec_task.family);
        let spec = RunSpec {
            task: task.into(),
            method: Method::Be { k: 4 },
            ratio: 0.5,
            seed: 3,
            scale: Scale::Tiny,
            epochs: Some(2),
        };
        let res = coordinator::run(rt, cache(), &spec).expect(task);
        let first = res.train.epoch_losses[0];
        let last = *res.train.epoch_losses.last().unwrap();
        assert!(last <= first * 1.05,
                "{task} loss exploded: {:?}", res.train.epoch_losses);
        assert!(res.score.is_finite() && res.score > 0.0,
                "{task} score {}", res.score);
    }
}

#[test]
fn classifier_beats_random_with_input_only_embedding() {
    let Some(rt) = runtime() else { return };
    let spec = RunSpec {
        task: "cade".into(),
        method: Method::Be { k: 4 },
        ratio: 0.1,
        seed: 5,
        scale: Scale::Tiny,
        epochs: Some(6),
    };
    let res = coordinator::run(rt, cache(), &spec).expect("cade");
    assert!(res.score > 2.0 * res.random_score,
            "acc {} vs random {}", res.score, res.random_score);
}

#[test]
fn baseline_runs_at_m_equals_d() {
    let Some(rt) = runtime() else { return };
    let spec = RunSpec {
        task: "bc".into(),
        method: Method::Baseline,
        ratio: 0.1, // ignored for Baseline
        seed: 2,
        scale: Scale::Tiny,
        epochs: Some(2),
    };
    let res = coordinator::run(rt, cache(), &spec).expect("baseline");
    assert_eq!(res.m, res.d);
    assert!(res.score.is_finite());
}

#[test]
fn dense_methods_run_with_cosine_artifacts() {
    let Some(rt) = runtime() else { return };
    for method in [Method::Pmi, Method::Cca] {
        let spec = RunSpec {
            task: "bc".into(),
            method,
            ratio: 0.1, // a bc test point: cosine artifacts exist there
            seed: 11,
            scale: Scale::Tiny,
            epochs: Some(2),
        };
        let res = coordinator::run(rt, cache(), &spec)
            .unwrap_or_else(|e| panic!("{method:?}: {e}"));
        assert!(res.score.is_finite());
        assert!(res.score >= 0.0);
    }
}

#[test]
fn predict_decode_artifact_matches_two_stage_decode() {
    let Some(rt) = runtime() else { return };
    // the fused artifact (predict + pallas bloom_decode) must agree with
    // rust-side decode over the plain predict artifact
    use bloomrec::bloom::HashMatrix;
    use bloomrec::model::ModelState;
    use bloomrec::runtime::{HostTensor, HostTensorI32};
    use bloomrec::util::rng::Rng;

    let fused_name = "ml_ff_ce_m152_predict_decode_d768_k4";
    let Some(fused_spec) = rt.manifest.artifact(fused_name).cloned()
    else {
        eprintln!("fused artifact missing, skipping");
        return;
    };
    let plain_spec = rt.manifest
        .find("ml", "predict", "softmax_ce", fused_spec.m_in)
        .expect("plain predict")
        .clone();

    let mut rng = Rng::new(13);
    let state = ModelState::init(&plain_spec, &mut rng);
    let hm = HashMatrix::random(fused_spec.decode_d, fused_spec.m_out,
                                fused_spec.decode_k, &mut rng);

    // random binary input batch
    let mut x = HostTensor::zeros(&plain_spec.x_shape());
    for v in x.data.iter_mut() {
        if rng.bool(0.03) {
            *v = 1.0;
        }
    }

    let plain = rt.load(&plain_spec.name).expect("load plain");
    let mut inputs: Vec<&HostTensor> = state.params.iter().collect();
    inputs.push(&x);
    let probs = plain.run(&inputs, &[]).expect("plain run")[0].clone();

    let fused = rt.load(fused_name).expect("load fused");
    let h = HostTensorI32 {
        shape: vec![fused_spec.decode_d, fused_spec.decode_k],
        data: hm.to_i32(),
    };
    let mut inputs: Vec<&HostTensor> = state.params.iter().collect();
    inputs.push(&x);
    let fused_scores = fused.run(&inputs, &[&h]).expect("fused run")[0]
        .clone();

    // rust-side decode of row 0
    let m = plain_spec.m_out;
    let d = fused_spec.decode_d;
    for row in [0usize, 5, 63] {
        let rust_scores = bloomrec::bloom::decode_scores(
            &probs.data[row * m..(row + 1) * m], &hm);
        let fused_row = &fused_scores.data[row * d..(row + 1) * d];
        for (i, (a, b)) in rust_scores.iter().zip(fused_row).enumerate() {
            assert!((a - b).abs() < 1e-3 * a.abs().max(1.0),
                    "row {row} item {i}: rust={a} fused={b}");
        }
    }
}

#[test]
fn evaluator_measures_agree_with_manifest_metric() {
    let Some(rt) = runtime() else { return };
    for t in &rt.manifest.tasks {
        assert!(Measure::parse(&t.metric).is_some(), "{}", t.metric);
    }
}
