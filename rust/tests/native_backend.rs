//! Native-backend correctness: finite-difference gradient checks of the
//! analytic backward passes (FF layers and GRU/LSTM truncated BPTT) on
//! tiny specs, property tests that the sparse (active-position) path
//! agrees bit-for-bit with the dense path for both forward and training
//! — flat rows and sequence minibatches alike — and property tests that
//! the data-parallel execution layer (micro-sharded `train_step`,
//! parallel kernels) is bit-identical to serial 1-shard execution for
//! every shard count and thread count.

use bloomrec::bloom::HashMatrix;
use bloomrec::embedding::{Bloom, Embedding};
use bloomrec::model::ModelState;
use bloomrec::runtime::{test_ff_spec, test_rnn_spec, ArtifactSpec,
                        BatchInput, BatchTarget, Execution, HostTensor,
                        NativeExecution, RecurrentExecution, SparseBatch,
                        SparseSeqBatch};
use bloomrec::util::proptest::check;
use bloomrec::util::rng::Rng;
use bloomrec::util::threadpool::WorkerPool;

/// Tests that mutate the process-global worker-pool size serialize on
/// this lock, so a concurrently running test cannot resize the pool
/// while a serial reference arm is mid-run (pool *readers* are safe —
/// results are thread-count-invariant — but the reference arms must
/// genuinely run serial to give the comparisons teeth).
static POOL_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn lock_pool() -> std::sync::MutexGuard<'static, ()> {
    POOL_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Loss at the given parameters (train_step reports the pre-update loss;
/// the mutated state is discarded).
fn loss_at(exe: &dyn Execution, params: &[HostTensor],
           opt_state: &[HostTensor], x: &BatchInput, y: &BatchTarget)
    -> f32 {
    let mut state = ModelState {
        params: params.to_vec(),
        opt_state: opt_state.to_vec(),
    };
    exe.train_step(&mut state, x, y).expect("train step")
}

/// Extract analytic gradients by running one plain-SGD step with lr = 1:
/// params' = params - grad.
fn analytic_grads(exe: &dyn Execution, state: &ModelState,
                  x: &BatchInput, y: &BatchTarget) -> Vec<Vec<f32>> {
    let mut s = state.clone();
    exe.train_step(&mut s, x, y).expect("train step");
    state
        .params
        .iter()
        .zip(&s.params)
        .map(|(old, new)| {
            old.data
                .iter()
                .zip(&new.data)
                .map(|(&o, &n)| o - n)
                .collect()
        })
        .collect()
}

/// Rewrite a spec into the plain-SGD lr=1 form `analytic_grads` needs.
fn sgd_lr1(spec: &mut ArtifactSpec) {
    spec.optimizer = "sgd".into();
    spec.opt_slots = 1;
    spec.opt_params.lr = 1.0;
    spec.opt_params.momentum = 0.0;
    spec.opt_params.clip_norm = 0.0;
}

/// Central-difference check of every bias coordinate and a deterministic
/// subset of the weights against the analytic gradients.
fn fd_check(exe: &dyn Execution, label: &str, state: &ModelState,
            x: &BatchInput, y: &BatchTarget, min_checked: usize) {
    let grads = analytic_grads(exe, state, x, y);
    let h = 1e-2f32;
    let mut checked = 0usize;
    for (pi, g) in grads.iter().enumerate() {
        for j in 0..g.len() {
            // probe every bias and a deterministic subset of the weights
            if g.len() > 12 && j % 7 != 0 {
                continue;
            }
            let mut plus = state.params.clone();
            plus[pi].data[j] += h;
            let mut minus = state.params.clone();
            minus[pi].data[j] -= h;
            let lp = loss_at(exe, &plus, &state.opt_state, x, y);
            let lm = loss_at(exe, &minus, &state.opt_state, x, y);
            let numeric = (lp - lm) / (2.0 * h);
            let analytic = g[j];
            let tol = 1e-3 + 0.02 * analytic.abs().max(numeric.abs());
            assert!(
                (numeric - analytic).abs() < tol,
                "{label}: param {pi}[{j}]: numeric {numeric} vs analytic \
                 {analytic}"
            );
            checked += 1;
        }
    }
    assert!(checked >= min_checked,
            "{label}: only {checked} coordinates checked");
}

fn finite_difference_check(loss: &str) {
    let mut spec = test_ff_spec(10, &[7], 6, 3);
    spec.loss = loss.into();
    sgd_lr1(&mut spec);
    let exe = NativeExecution::new(spec.clone()).unwrap();

    let mut rng = Rng::new(0xF1D0 ^ loss.len() as u64);
    let state = ModelState::init(&spec, &mut rng);
    // random sparse-ish input and target batch (row 2 left empty on the
    // input side to exercise the zero-padded-row path)
    let mut x = HostTensor::zeros(&[3, 10]);
    let mut y = HostTensor::zeros(&[3, 6]);
    for (j, v) in x.data.iter_mut().enumerate() {
        if j < 20 && rng.bool(0.4) {
            *v = 1.0;
        }
    }
    for v in y.data.iter_mut() {
        if rng.bool(0.4) {
            *v = 1.0;
        }
    }
    let x = BatchInput::Dense(x);
    let y = BatchTarget::Dense(y);
    fd_check(&exe, loss, &state, &x, &y, 25);
}

#[test]
fn gradient_check_softmax_ce() {
    finite_difference_check("softmax_ce");
}

#[test]
fn gradient_check_cosine() {
    finite_difference_check("cosine");
}

/// BPTT gradient check for the recurrent cells: every wire tensor (wx,
/// wh, bg, wo, bo) against central differences, with a left-padded row
/// exercising the zero-input-step path.
fn finite_difference_check_rnn(family: &str, loss: &str) {
    let mut spec = test_rnn_spec(family, 8, 5, 7, 2, 3);
    spec.loss = loss.into();
    sgd_lr1(&mut spec);
    let exe = RecurrentExecution::new(spec.clone()).unwrap();

    let mut rng = Rng::new(0xB117 ^ (family.len() as u64)
                           ^ ((loss.len() as u64) << 8));
    let state = ModelState::init(&spec, &mut rng);
    // one active bit per (row, step); row 1 step 0 stays a padding step
    let mut x = HostTensor::zeros(&[2, 3, 8]);
    for r in 0..2usize {
        for t in 0..3usize {
            if r == 1 && t == 0 {
                continue;
            }
            let j = rng.below(8);
            x.data[(r * 3 + t) * 8 + j] = 1.0;
        }
    }
    let mut y = HostTensor::zeros(&[2, 7]);
    for v in y.data.iter_mut() {
        if rng.bool(0.4) {
            *v = 1.0;
        }
    }
    let x = BatchInput::Dense(x);
    let y = BatchTarget::Dense(y);
    fd_check(&exe, &format!("{family}/{loss}"), &state, &x, &y, 30);
}

#[test]
fn gradient_check_gru() {
    finite_difference_check_rnn("gru", "softmax_ce");
}

#[test]
fn gradient_check_lstm() {
    finite_difference_check_rnn("lstm", "softmax_ce");
}

#[test]
fn gradient_check_gru_cosine() {
    finite_difference_check_rnn("gru", "cosine");
}

#[test]
fn gradient_check_lstm_cosine() {
    finite_difference_check_rnn("lstm", "cosine");
}

/// Random Bloom-encoded batches: the sparse forward must equal the dense
/// forward bit-for-bit (identical accumulation order by construction).
#[test]
fn prop_sparse_and_dense_forward_agree_exactly() {
    check("sparse-dense-forward", 0xB0, 30,
          |rng| {
              let d = 20 + rng.below(200);
              let m = 8 + rng.below(40);
              let k = 1 + rng.below(4.min(m));
              let batch = 1 + rng.below(8);
              let rows = rng.below(batch + 1);
              let seed = rng.next_u64();
              (vec![d, m, k, batch, rows], seed)
          },
          |input| {
              let (dims, seed) = input;
              if dims.len() != 5 {
                  return Ok(()); // shrunk out of shape
              }
              let (d, m, k, batch, rows) =
                  (dims[0], dims[1], dims[2], dims[3], dims[4]);
              if d == 0 || m == 0 || k == 0 || k > m || batch == 0
                  || rows > batch {
                  return Ok(()); // shrunk outside the invariants
              }
              let mut rng = Rng::new(*seed);
              let mut spec = test_ff_spec(m, &[11], m, batch);
              spec.kind = "predict".into();
              spec.opt_slots = 0;
              let exe = NativeExecution::new(spec.clone()).unwrap();
              let state = ModelState::init(&spec, &mut rng);
              let emb =
                  Bloom::new(HashMatrix::random(d, m, k, &mut rng), None);

              let mut sb = SparseBatch::new(m);
              let mut dense = HostTensor::zeros(&[batch, m]);
              let mut scratch = Vec::new();
              for r in 0..rows {
                  let c = 1 + rng.below(10.min(d));
                  let items: Vec<u32> = rng
                      .sample_distinct(d, c)
                      .into_iter()
                      .map(|i| i as u32)
                      .collect();
                  if !emb.encode_input_sparse(&items, &mut scratch) {
                      return Err("bloom must encode sparsely".into());
                  }
                  sb.push_row(&scratch);
                  emb.encode_input(&items,
                                   &mut dense.data[r * m..(r + 1) * m]);
              }

              let sparse_out = exe
                  .predict(&state.params, &BatchInput::Sparse(sb))
                  .map_err(|e| e.to_string())?;
              let dense_out = exe
                  .predict(&state.params, &BatchInput::Dense(dense))
                  .map_err(|e| e.to_string())?;
              if sparse_out != dense_out {
                  return Err(format!(
                      "forward mismatch at d={d} m={m} k={k} \
                       batch={batch} rows={rows}"));
              }
              Ok(())
          });
}

/// One training step from identical states must produce identical
/// parameters whether the batch went in sparse or dense.
#[test]
fn prop_sparse_and_dense_train_step_agree_exactly() {
    check("sparse-dense-train", 0xB1, 20,
          |rng| {
              let d = 30 + rng.below(100);
              let m = 8 + rng.below(24);
              let k = 1 + rng.below(4.min(m));
              let batch = 1 + rng.below(6);
              let seed = rng.next_u64();
              (vec![d, m, k, batch], seed)
          },
          |input| {
              let (dims, seed) = input;
              if dims.len() != 4 {
                  return Ok(()); // shrunk out of shape
              }
              let (d, m, k, batch) = (dims[0], dims[1], dims[2], dims[3]);
              if d == 0 || m == 0 || k == 0 || k > m || batch == 0 {
                  return Ok(()); // shrunk outside the invariants
              }
              let mut rng = Rng::new(*seed);
              let spec = test_ff_spec(m, &[9], m, batch);
              let exe = NativeExecution::new(spec.clone()).unwrap();
              let state0 = ModelState::init(&spec, &mut rng);
              let emb =
                  Bloom::new(HashMatrix::random(d, m, k, &mut rng), None);

              let mut sb = SparseBatch::new(m);
              let mut dense = HostTensor::zeros(&[batch, m]);
              let mut y = HostTensor::zeros(&[batch, m]);
              let mut scratch = Vec::new();
              for r in 0..batch {
                  let c = 1 + rng.below(6.min(d));
                  let items: Vec<u32> = rng
                      .sample_distinct(d, c)
                      .into_iter()
                      .map(|i| i as u32)
                      .collect();
                  emb.encode_input_sparse(&items, &mut scratch);
                  sb.push_row(&scratch);
                  emb.encode_input(&items,
                                   &mut dense.data[r * m..(r + 1) * m]);
                  let t = 1 + rng.below(4.min(d));
                  let targets: Vec<u32> = rng
                      .sample_distinct(d, t)
                      .into_iter()
                      .map(|i| i as u32)
                      .collect();
                  emb.encode_target(&targets,
                                    &mut y.data[r * m..(r + 1) * m]);
              }

              let y = BatchTarget::Dense(y);
              let mut s_sparse = state0.clone();
              let l_sparse = exe
                  .train_step(&mut s_sparse, &BatchInput::Sparse(sb), &y)
                  .map_err(|e| e.to_string())?;
              let mut s_dense = state0.clone();
              let l_dense = exe
                  .train_step(&mut s_dense, &BatchInput::Dense(dense), &y)
                  .map_err(|e| e.to_string())?;
              if l_sparse != l_dense {
                  return Err(format!(
                      "loss mismatch: {l_sparse} vs {l_dense}"));
              }
              if s_sparse.params != s_dense.params
                  || s_sparse.opt_state != s_dense.opt_state
              {
                  return Err(format!(
                      "state mismatch at d={d} m={m} k={k} batch={batch}"));
              }
              Ok(())
          });
}

/// One training step from identical states must produce identical
/// parameters whether the TARGETS went in sparse or dense — the output
/// side of the sparse-first pipeline (`BatchTarget::Sparse`), across
/// both loss families and both model families.
#[test]
fn prop_sparse_and_dense_targets_agree_exactly() {
    check("sparse-dense-targets", 0xB4, 16,
          |rng| {
              let d = 30 + rng.below(80);
              let m = 8 + rng.below(16);
              let k = 1 + rng.below(4.min(m));
              let batch = 1 + rng.below(5);
              let recurrent = rng.below(2);
              let cosine = rng.below(2);
              let seed = rng.next_u64();
              (vec![d, m, k, batch, recurrent, cosine], seed)
          },
          |input| {
              let (dims, seed) = input;
              if dims.len() != 6 {
                  return Ok(()); // shrunk out of shape
              }
              let (d, m, k, batch, recurrent, cosine) =
                  (dims[0], dims[1], dims[2], dims[3], dims[4], dims[5]);
              if d == 0 || m == 0 || k == 0 || k > m || batch == 0 {
                  return Ok(()); // shrunk outside the invariants
              }
              let mut rng = Rng::new(*seed);
              let loss = if cosine == 1 { "cosine" } else { "softmax_ce" };
              let (exe, spec): (Box<dyn Execution>, ArtifactSpec) =
                  if recurrent == 1 {
                      let mut spec = test_rnn_spec("gru", m, 5, m, batch,
                                                   3);
                      spec.loss = loss.into();
                      (Box::new(RecurrentExecution::new(spec.clone())
                           .unwrap()), spec)
                  } else {
                      let mut spec = test_ff_spec(m, &[9], m, batch);
                      spec.loss = loss.into();
                      (Box::new(NativeExecution::new(spec.clone())
                           .unwrap()), spec)
                  };
              let state0 = ModelState::init(&spec, &mut rng);
              let emb =
                  Bloom::new(HashMatrix::random(d, m, k, &mut rng), None);

              // random input batch (family-appropriate)
              let x = if recurrent == 1 {
                  let (sb, _) = random_seq_batches(&emb, d, m, batch,
                                                   batch, 3, &mut rng);
                  BatchInput::SparseSeq(sb)
              } else {
                  let mut sb = SparseBatch::new(m);
                  let mut scratch = Vec::new();
                  for _ in 0..batch {
                      let item = rng.below(d) as u32;
                      emb.encode_input_sparse(&[item], &mut scratch);
                      sb.push_row(&scratch);
                  }
                  BatchInput::Sparse(sb)
              };
              // identical targets, sparse and dense; the last row stays
              // empty/zero to exercise the padding-row arm
              let mut ysb = SparseBatch::new(m);
              let mut ydense = HostTensor::zeros(&[batch, m]);
              let mut scratch = Vec::new();
              for r in 0..batch.saturating_sub(1) {
                  let t = 1 + rng.below(3.min(d));
                  let targets: Vec<u32> = rng
                      .sample_distinct(d, t)
                      .into_iter()
                      .map(|i| i as u32)
                      .collect();
                  if !emb.encode_target_sparse(&targets, &mut scratch) {
                      return Err("bloom must encode targets sparsely"
                          .into());
                  }
                  ysb.push_row(&scratch);
                  emb.encode_target(&targets,
                                    &mut ydense.data[r * m..(r + 1) * m]);
              }

              let mut s_sparse = state0.clone();
              let l_sparse = exe
                  .train_step(&mut s_sparse, &x,
                              &BatchTarget::Sparse(ysb))
                  .map_err(|e| e.to_string())?;
              let mut s_dense = state0.clone();
              let l_dense = exe
                  .train_step(&mut s_dense, &x,
                              &BatchTarget::Dense(ydense))
                  .map_err(|e| e.to_string())?;
              if l_sparse != l_dense {
                  return Err(format!(
                      "{loss} target loss mismatch: {l_sparse} vs \
                       {l_dense}"));
              }
              if s_sparse.params != s_dense.params
                  || s_sparse.opt_state != s_dense.opt_state
              {
                  return Err(format!(
                      "{loss} target state mismatch at d={d} m={m} k={k} \
                       batch={batch} recurrent={recurrent}"));
              }
              Ok(())
          });
}

/// Build matching sparse and dense sequence batches: Bloom-encoded
/// windows with a random number of leading padding steps per row.
fn random_seq_batches(emb: &Bloom, d: usize, m: usize, batch: usize,
                      rows: usize, t_len: usize, rng: &mut Rng)
    -> (SparseSeqBatch, HostTensor) {
    let mut sb = SparseSeqBatch::new(m, t_len);
    let mut dense = HostTensor::zeros(&[batch, t_len, m]);
    let mut scratch = Vec::new();
    for r in 0..rows {
        let pads = rng.below(t_len);
        for t in 0..t_len {
            if t < pads {
                sb.push_step(&[]);
                continue;
            }
            let item = rng.below(d) as u32;
            assert!(emb.encode_input_sparse(&[item], &mut scratch));
            sb.push_step(&scratch);
            let lo = (r * t_len + t) * m;
            emb.encode_input(&[item], &mut dense.data[lo..lo + m]);
        }
    }
    (sb, dense)
}

/// Random Bloom-encoded sequence batches: the sparse per-timestep
/// forward must equal the dense [batch, T, m] forward bit-for-bit.
#[test]
fn prop_sparse_and_dense_seq_forward_agree_exactly() {
    check("sparse-dense-seq-forward", 0xB2, 20,
          |rng| {
              let d = 20 + rng.below(150);
              let m = 8 + rng.below(24);
              let k = 1 + rng.below(4.min(m));
              let batch = 1 + rng.below(5);
              let rows = rng.below(batch + 1);
              let t_len = 2 + rng.below(5);
              let seed = rng.next_u64();
              (vec![d, m, k, batch, rows, t_len], seed)
          },
          |input| {
              let (dims, seed) = input;
              if dims.len() != 6 {
                  return Ok(()); // shrunk out of shape
              }
              let (d, m, k, batch, rows, t_len) =
                  (dims[0], dims[1], dims[2], dims[3], dims[4], dims[5]);
              if d == 0 || m == 0 || k == 0 || k > m || batch == 0
                  || rows > batch || t_len == 0 {
                  return Ok(()); // shrunk outside the invariants
              }
              let mut rng = Rng::new(*seed);
              let mut spec = test_rnn_spec("gru", m, 6, m, batch, t_len);
              spec.kind = "predict".into();
              spec.opt_slots = 0;
              let exe = RecurrentExecution::new(spec.clone()).unwrap();
              let state = ModelState::init(&spec, &mut rng);
              let emb =
                  Bloom::new(HashMatrix::random(d, m, k, &mut rng), None);
              let (sb, dense) = random_seq_batches(&emb, d, m, batch,
                                                   rows, t_len, &mut rng);
              let sparse_out = exe
                  .predict(&state.params, &BatchInput::SparseSeq(sb))
                  .map_err(|e| e.to_string())?;
              let dense_out = exe
                  .predict(&state.params, &BatchInput::Dense(dense))
                  .map_err(|e| e.to_string())?;
              if sparse_out != dense_out {
                  return Err(format!(
                      "seq forward mismatch at d={d} m={m} k={k} \
                       batch={batch} rows={rows} t={t_len}"));
              }
              Ok(())
          });
}

/// One recurrent training step from identical states must produce
/// identical parameters whether the sequences went in sparse or dense.
#[test]
fn prop_sparse_and_dense_seq_train_step_agree_exactly() {
    check("sparse-dense-seq-train", 0xB3, 12,
          |rng| {
              let d = 30 + rng.below(80);
              let m = 8 + rng.below(16);
              let k = 1 + rng.below(4.min(m));
              let batch = 1 + rng.below(4);
              let t_len = 2 + rng.below(4);
              let lstm = rng.below(2);
              let seed = rng.next_u64();
              (vec![d, m, k, batch, t_len, lstm], seed)
          },
          |input| {
              let (dims, seed) = input;
              if dims.len() != 6 {
                  return Ok(()); // shrunk out of shape
              }
              let (d, m, k, batch, t_len, lstm) =
                  (dims[0], dims[1], dims[2], dims[3], dims[4], dims[5]);
              if d == 0 || m == 0 || k == 0 || k > m || batch == 0
                  || t_len == 0 {
                  return Ok(()); // shrunk outside the invariants
              }
              let family = if lstm == 1 { "lstm" } else { "gru" };
              let mut rng = Rng::new(*seed);
              let spec = test_rnn_spec(family, m, 5, m, batch, t_len);
              let exe = RecurrentExecution::new(spec.clone()).unwrap();
              let state0 = ModelState::init(&spec, &mut rng);
              let emb =
                  Bloom::new(HashMatrix::random(d, m, k, &mut rng), None);
              let (sb, dense) = random_seq_batches(&emb, d, m, batch,
                                                   batch, t_len,
                                                   &mut rng);
              let mut y = HostTensor::zeros(&[batch, m]);
              for r in 0..batch {
                  let target = rng.below(d) as u32;
                  emb.encode_target(&[target],
                                    &mut y.data[r * m..(r + 1) * m]);
              }

              let y = BatchTarget::Dense(y);
              let mut s_sparse = state0.clone();
              let l_sparse = exe
                  .train_step(&mut s_sparse, &BatchInput::SparseSeq(sb),
                              &y)
                  .map_err(|e| e.to_string())?;
              let mut s_dense = state0.clone();
              let l_dense = exe
                  .train_step(&mut s_dense, &BatchInput::Dense(dense),
                              &y)
                  .map_err(|e| e.to_string())?;
              if l_sparse != l_dense {
                  return Err(format!(
                      "{family} loss mismatch: {l_sparse} vs {l_dense}"));
              }
              if s_sparse.params != s_dense.params
                  || s_sparse.opt_state != s_dense.opt_state
              {
                  return Err(format!(
                      "{family} state mismatch at d={d} m={m} k={k} \
                       batch={batch} t={t_len}"));
              }
              Ok(())
          });
}

/// Recurrent training on the native backend actually learns: loss
/// decreases over repeated steps on a deterministic next-item problem.
#[test]
fn recurrent_training_reduces_loss() {
    for family in ["gru", "lstm"] {
        let mut spec = test_rnn_spec(family, 16, 8, 16, 4, 3);
        spec.opt_params.lr = 0.02;
        let exe = RecurrentExecution::new(spec.clone()).unwrap();
        let mut rng = Rng::new(99);
        let mut state = ModelState::init(&spec, &mut rng);
        let emb =
            Bloom::new(HashMatrix::random(48, 16, 3, &mut rng), None);

        // fixed supervised windows: [i, i+1, i+2] predicts i+3
        let mut sb = SparseSeqBatch::new(16, 3);
        let mut y = HostTensor::zeros(&[4, 16]);
        let mut scratch = Vec::new();
        for r in 0..4u32 {
            for t in 0..3u32 {
                emb.encode_input_sparse(&[r * 11 + t], &mut scratch);
                sb.push_step(&scratch);
            }
            emb.encode_target(&[r * 11 + 3],
                              &mut y.data[r as usize * 16
                                  ..(r as usize + 1) * 16]);
        }
        let x = BatchInput::SparseSeq(sb);
        let y = BatchTarget::Dense(y);
        let first = exe.train_step(&mut state, &x, &y).unwrap();
        let mut last = first;
        for _ in 0..120 {
            last = exe.train_step(&mut state, &x, &y).unwrap();
        }
        assert!(last < first * 0.8,
                "{family}: loss did not decrease: first {first}, \
                 last {last}");
    }
}

/// Training on the native backend actually learns: loss decreases over
/// steps on a deterministic toy problem.
#[test]
fn native_training_reduces_loss() {
    let mut spec = test_ff_spec(24, &[16], 24, 8);
    spec.opt_params.lr = 0.01;
    let exe = NativeExecution::new(spec.clone()).unwrap();
    let mut rng = Rng::new(77);
    let mut state = ModelState::init(&spec, &mut rng);
    let emb = Bloom::new(HashMatrix::random(64, 24, 3, &mut rng), None);

    // fixed supervised pairs: input item 7i predicts item 7i + 1
    let inputs: Vec<Vec<u32>> = (0..8u32).map(|i| vec![i * 7]).collect();
    let mut x = HostTensor::zeros(&[8, 24]);
    let mut y = HostTensor::zeros(&[8, 24]);
    for (r, items) in inputs.iter().enumerate() {
        emb.encode_input(items, &mut x.data[r * 24..(r + 1) * 24]);
        let target = vec![items[0] + 1];
        emb.encode_target(&target, &mut y.data[r * 24..(r + 1) * 24]);
    }
    let x = BatchInput::Dense(x);
    let y = BatchTarget::Dense(y);
    let first = exe.train_step(&mut state, &x, &y).unwrap();
    let mut last = first;
    for _ in 0..150 {
        last = exe.train_step(&mut state, &x, &y).unwrap();
    }
    assert!(last < first * 0.8,
            "loss did not decrease: first {first}, last {last}");
}

/// Random sparse FF batch + target for the sharding properties: `rows`
/// live rows (possibly fewer than the spec batch — the ragged tail),
/// ascending unique positions per row.
fn random_ff_batch(rng: &mut Rng, m_in: usize, m_out: usize, rows: usize)
    -> (BatchInput, BatchTarget) {
    let mut x = SparseBatch::new(m_in);
    let mut y = SparseBatch::new(m_out);
    for _ in 0..rows {
        let nnz = 1 + rng.below(m_in.min(4));
        let mut pos: Vec<usize> = rng.sample_distinct(m_in, nnz);
        pos.sort_unstable();
        let row: Vec<(u32, f32)> =
            pos.iter().map(|&j| (j as u32, 1.0)).collect();
        x.push_row(&row);
        let nnz = 1 + rng.below(m_out.min(3));
        let mut pos: Vec<usize> = rng.sample_distinct(m_out, nnz);
        pos.sort_unstable();
        let row: Vec<(u32, f32)> =
            pos.iter().map(|&j| (j as u32, 1.0)).collect();
        y.push_row(&row);
    }
    (BatchInput::Sparse(x), BatchTarget::Sparse(y))
}

/// The S-shard `train_step` must be bit-identical to the serial 1-shard
/// arm — same loss, same updated parameters and optimizer state — for
/// random shapes, ragged shard sizes (shards that do not divide the
/// batch, shards exceeding the row count) and thread counts. This is
/// the data-parallel trainer's core guarantee: the loss curve never
/// depends on how the minibatch was sharded or how many workers ran it.
#[test]
fn prop_sharded_train_step_bit_identical_to_serial() {
    let _pool = lock_pool();
    check("sharded-train-vs-serial", 0x5AD3, 8,
          |rng| {
              let m_in = 8 + rng.below(24);
              let hidden = 4 + rng.below(12);
              let m_out = 8 + rng.below(24);
              let batch = 2 + rng.below(11);
              let rows = 1 + rng.below(batch);
              let seed = rng.next_u64();
              (vec![m_in, hidden, m_out, batch, rows], seed)
          },
          |input| {
              let (dims, seed) = input;
              if dims.len() != 5 {
                  return Ok(()); // shrunk out of shape
              }
              let (m_in, hidden, m_out, batch, rows) =
                  (dims[0], dims[1], dims[2], dims[3], dims[4]);
              if m_in == 0 || hidden == 0 || m_out == 0 || batch == 0
                  || rows == 0 || rows > batch {
                  return Ok(()); // shrunk outside the invariants
              }
              let mut rng = Rng::new(*seed);
              let spec = test_ff_spec(m_in, &[hidden], m_out, batch);
              let exe = NativeExecution::new(spec.clone())
                  .map_err(|e| e.to_string())?;
              let state = ModelState::init(&spec, &mut rng);
              let (x, y) = random_ff_batch(&mut rng, m_in, m_out, rows);

              // serial reference: one shard, one worker
              WorkerPool::set_global_threads(1);
              let mut s_ref = state.clone();
              let l_ref = exe.train_step_sharded(&mut s_ref, &x, &y, 1)
                  .map_err(|e| e.to_string())?;

              for &(shards, threads) in
                  &[(0usize, 1usize), (0, 4), (1, 3), (2, 2), (3, 1),
                    (batch, 4), (batch + 5, 2)]
              {
                  WorkerPool::set_global_threads(threads);
                  let mut s = state.clone();
                  let l = exe.train_step_sharded(&mut s, &x, &y, shards)
                      .map_err(|e| e.to_string())?;
                  if l.to_bits() != l_ref.to_bits() {
                      return Err(format!(
                          "loss diverged: {l} vs {l_ref} \
                           (shards={shards}, threads={threads})"));
                  }
                  if s.params != s_ref.params
                      || s.opt_state != s_ref.opt_state {
                      return Err(format!(
                          "updated state diverged \
                           (shards={shards}, threads={threads})"));
                  }
              }
              WorkerPool::set_global_threads(0);
              Ok(())
          });
}

/// Multi-step determinism: the whole LOSS TRAJECTORY (optimizer state
/// threaded across steps) is identical between a serial single-worker
/// run and sharded multi-worker runs.
#[test]
fn sharded_training_loss_trajectory_is_bit_identical() {
    let _pool = lock_pool();
    // 64 x 128 x 128 layer products clear the kernels' fan-out
    // threshold, so multi-worker runs genuinely split the work
    let spec = test_ff_spec(128, &[128], 128, 64);
    let exe = NativeExecution::new(spec.clone()).unwrap();
    let mut rng = Rng::new(0x70AD);
    let state0 = ModelState::init(&spec, &mut rng);
    let batches: Vec<(BatchInput, BatchTarget)> = (0..4)
        .map(|_| random_ff_batch(&mut rng, 128, 128, 64))
        .collect();

    let run = |shards: usize, threads: usize| -> (Vec<u32>, ModelState) {
        WorkerPool::set_global_threads(threads);
        let mut state = state0.clone();
        let mut losses = Vec::new();
        for (x, y) in &batches {
            let l = exe.train_step_sharded(&mut state, x, y, shards)
                .expect("train step");
            losses.push(l.to_bits());
        }
        (losses, state)
    };
    let (curve_ref, state_ref) = run(1, 1);
    for (shards, threads) in [(0, 4), (2, 2), (5, 4), (64, 8)] {
        let (curve, state) = run(shards, threads);
        assert_eq!(curve, curve_ref,
                   "loss curve diverged (shards={shards}, \
                    threads={threads})");
        assert_eq!(state.params, state_ref.params,
                   "params diverged (shards={shards}, \
                    threads={threads})");
        assert_eq!(state.opt_state, state_ref.opt_state,
                   "opt state diverged (shards={shards}, \
                    threads={threads})");
    }
    WorkerPool::set_global_threads(0);
}

/// Recurrent training is parallel inside each timestep (row-blocked
/// kernels); its results must also be independent of the worker count —
/// exercised at a shape big enough that the gate GEMMs genuinely fan
/// out (64 rows x 64 hidden x 4*64 gate columns > the kernel
/// threshold).
#[test]
fn recurrent_train_step_bit_identical_across_thread_counts() {
    let _pool = lock_pool();
    for family in ["gru", "lstm"] {
        let (m, h, batch, t_len) = (64usize, 64usize, 64usize, 3usize);
        let spec = test_rnn_spec(family, m, h, m, batch, t_len);
        let exe = RecurrentExecution::new(spec.clone()).unwrap();
        let mut rng = Rng::new(0x7EC4);
        let state0 = ModelState::init(&spec, &mut rng);
        let mut x = SparseSeqBatch::new(m, t_len);
        let mut y = SparseBatch::new(m);
        for _ in 0..batch {
            for t in 0..t_len {
                if t == 0 && rng.bool(0.3) {
                    x.push_step(&[]); // leading pad
                } else {
                    let mut pos: Vec<usize> = rng.sample_distinct(m, 3);
                    pos.sort_unstable();
                    let row: Vec<(u32, f32)> =
                        pos.iter().map(|&j| (j as u32, 1.0)).collect();
                    x.push_step(&row);
                }
            }
            y.push_row(&[(rng.below(m) as u32, 1.0)]);
        }
        let x = BatchInput::SparseSeq(x);
        let y = BatchTarget::Sparse(y);

        WorkerPool::set_global_threads(1);
        let mut s_ref = state0.clone();
        let l_ref = exe.train_step(&mut s_ref, &x, &y).unwrap();
        for threads in [2usize, 4, 7] {
            WorkerPool::set_global_threads(threads);
            let mut s = state0.clone();
            // the shard hint is a no-op for recurrent training but must
            // stay bit-identical through the sharded entry point too
            let l = exe.train_step_sharded(&mut s, &x, &y, threads)
                .unwrap();
            assert_eq!(l.to_bits(), l_ref.to_bits(),
                       "{family}: loss diverged at threads={threads}");
            assert_eq!(s.params, s_ref.params,
                       "{family}: params diverged at threads={threads}");
            assert_eq!(s.opt_state, s_ref.opt_state,
                       "{family}: opt state diverged at \
                        threads={threads}");
        }
    }
    WorkerPool::set_global_threads(0);
}
