//! Quantized-tier integration properties — the error-bound and
//! determinism contracts of the int8 serving path:
//!
//! * property test that quantize -> dequantize stays within the
//!   per-block half-scale bound (`PackedBQ8::qerr_bound`) on random
//!   shapes;
//! * property test that `gemm_q8` stays within the propagated interval
//!   bound `|C_q - C| <= sum_k |a[i,k]| * qerr(k,j)` of the f32 kernel
//!   on random shapes spanning the tile/panel edges;
//! * the int8 kernels (`gemm_q8`, `spmm_gather_q8`) are bit-identical
//!   across every SIMD level this host supports — the tier keeps the
//!   repo's dispatch invariant *within itself*;
//! * the full quantized forward pass is level-invariant, agrees bitwise
//!   between its dense and sparse input paths, and tracks the f32
//!   oracle within a layer-propagated interval bound (quantization
//!   error + f16 activation rounding, ReLU 1-Lipschitz, softmax
//!   Jacobian row-l1 <= 1/2);
//! * the f16 conversion contract: round trip within half an ulp on
//!   normals, half a quantum on subnormals, saturation only at the top
//!   of the range, NaN never collapsing to inf.

use bloomrec::linalg::simd::{self, SimdLevel};
use bloomrec::linalg::{gemm_q8, spmm_gather_q8, PackedBQ8};
use bloomrec::model::ModelState;
use bloomrec::runtime::{test_ff_spec, BatchInput, Execution, HostTensor,
                        NativeExecution, QTensor, SparseBatch};
use bloomrec::util::f16::{f16_from_f32, f16_to_f32};
use bloomrec::util::proptest::check;
use bloomrec::util::rng::Rng;

/// Tests that force the process-global SIMD dispatch level serialize on
/// this lock (same pattern as `tests/kernels.rs`): results are
/// level-invariant by contract, but the reference arms must genuinely
/// run scalar while they execute.
static SIMD_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

/// Scalar plus every SIMD level this host can actually execute.
fn supported_simd_levels() -> Vec<SimdLevel> {
    let mut out = vec![SimdLevel::Scalar];
    for l in [SimdLevel::Sse, SimdLevel::Avx2, SimdLevel::Neon] {
        simd::set_level(Some(l));
        if simd::level() == l {
            out.push(l);
        }
    }
    simd::set_level(None);
    out
}

fn rand_vec(rng: &mut Rng, len: usize, sparsity: f64) -> Vec<f32> {
    (0..len)
        .map(|_| {
            if rng.bool(sparsity) {
                0.0
            } else {
                rng.normal() as f32
            }
        })
        .collect()
}

/// quantize -> dequantize round trip within the advertised per-block
/// bound, at random shapes spanning the NR/KC block edges.
#[test]
fn prop_quantize_round_trip_within_per_block_bound() {
    check("q8-round-trip", 0x51AB, 30,
          |rng| {
              (vec![1 + rng.below(400), 1 + rng.below(150)],
               rng.next_u64())
          },
          |input| {
              let (dims, seed) = input;
              if dims.len() != 2 {
                  return Ok(()); // shrunk out of shape
              }
              let (k, n) = (dims[0], dims[1]);
              if k == 0 || n == 0 {
                  return Ok(());
              }
              let mut rng = Rng::new(*seed);
              // mix magnitudes so blocks carry different scales
              let b: Vec<f32> = (0..k * n)
                  .map(|i| {
                      if rng.bool(0.2) {
                          0.0
                      } else {
                          rng.normal() as f32 * (1 + i % 7) as f32
                      }
                  })
                  .collect();
              let q = PackedBQ8::quantize(&b, k, n);
              let back = q.dequantize();
              for kk in 0..k {
                  for j in 0..n {
                      let err = (b[kk * n + j] - back[kk * n + j]).abs();
                      let bound = q.qerr_bound(kk, j);
                      if err > bound {
                          return Err(format!(
                              "[{kk},{j}] of [{k},{n}]: \
                               err {err} > bound {bound}"));
                      }
                  }
              }
              Ok(())
          });
}

/// `gemm_q8` vs the f32 blocked kernel within the interval bound
/// `sum_k |a[i,k]| * qerr(k,j)` plus float slop, on random shapes.
#[test]
fn prop_gemm_q8_within_propagated_interval_bound() {
    use bloomrec::linalg::gemm::gemm;
    check("gemm-q8-bound", 0x51AC, 25,
          |rng| {
              (vec![1 + rng.below(9), 1 + rng.below(320),
                    1 + rng.below(140)],
               rng.next_u64())
          },
          |input| {
              let (dims, seed) = input;
              if dims.len() != 3 {
                  return Ok(());
              }
              let (m, k, n) = (dims[0], dims[1], dims[2]);
              if m == 0 || k == 0 || n == 0 {
                  return Ok(());
              }
              let mut rng = Rng::new(*seed);
              let a = rand_vec(&mut rng, m * k, 0.3);
              let b = rand_vec(&mut rng, k * n, 0.0);
              let q = PackedBQ8::quantize(&b, k, n);
              let mut want = vec![0.0f32; m * n];
              gemm(&a, &b, &mut want, m, k, n, 0.0);
              let mut got = vec![0.0f32; m * n];
              gemm_q8(&a, &q, &mut got, m, k, n, 0.0);
              for i in 0..m {
                  for j in 0..n {
                      let mut bound = 1.0e-5f32;
                      for kk in 0..k {
                          bound += a[i * k + kk].abs()
                              * q.qerr_bound(kk, j)
                              + 1.0e-7;
                      }
                      let err = (want[i * n + j] - got[i * n + j]).abs();
                      if err > bound {
                          return Err(format!(
                              "({i},{j}) of {m}x{k}x{n}: \
                               {err} > {bound}"));
                      }
                  }
              }
              Ok(())
          });
}

/// The int8 kernels must be bit-identical to their forced-scalar arms
/// at every SIMD level, across shapes covering every lane-tail width
/// of the NR = 64 column tile and the KC = 256 panel edge.
#[test]
fn int8_kernels_bit_identical_across_simd_levels() {
    let _g = SIMD_LOCK.lock().unwrap();
    let levels = supported_simd_levels();
    let mut rng = Rng::new(0x51AD);
    for &(m, k, n) in &[(1usize, 1usize, 1usize), (3, 5, 7), (4, 64, 64),
                        (5, 257, 130), (6, 300, 65), (2, 31, 97)] {
        let a = rand_vec(&mut rng, m * k, 0.3);
        let b = rand_vec(&mut rng, k * n, 0.1);
        let q = PackedBQ8::quantize(&b, k, n);
        let seed_c = rand_vec(&mut rng, m * n, 0.0);

        // sparse operand describing the same dense A, row by row
        let mut indptr = vec![0usize];
        let mut indices = Vec::new();
        let mut vals = Vec::new();
        for i in 0..m {
            for kk in 0..k {
                let v = a[i * k + kk];
                if v != 0.0 {
                    indices.push(kk as u32);
                    vals.push(v);
                }
            }
            indptr.push(indices.len());
        }

        simd::set_level(Some(SimdLevel::Scalar));
        let mut want_g = seed_c.clone();
        gemm_q8(&a, &q, &mut want_g, m, k, n, 1.0);
        let mut want_s = seed_c.clone();
        spmm_gather_q8(&indptr, &indices, &vals, m, 0, 1, &q,
                       &mut want_s);

        for &l in &levels {
            simd::set_level(Some(l));
            let mut c = seed_c.clone();
            gemm_q8(&a, &q, &mut c, m, k, n, 1.0);
            assert_eq!(c, want_g,
                       "gemm_q8 diverged at level {} on {m}x{k}x{n}",
                       l.name());
            let mut c = seed_c.clone();
            spmm_gather_q8(&indptr, &indices, &vals, m, 0, 1, &q,
                           &mut c);
            assert_eq!(c, want_s,
                       "spmm_gather_q8 diverged at level {} on \
                        {m}x{k}x{n}", l.name());
        }
        simd::set_level(None);
    }
}

/// Naive f64 forward pass capturing per-layer post-ReLU activations —
/// the "exact arithmetic" reference for the interval propagation.
fn naive_forward(params: &[HostTensor], x: &[f32], batch: usize)
    -> (Vec<Vec<f64>>, Vec<f64>) {
    let mut acts: Vec<Vec<f64>> = Vec::new();
    let mut a: Vec<f64> = x.iter().map(|&v| v as f64).collect();
    let layers = params.len() / 2;
    for l in 0..layers {
        let w = &params[2 * l];
        let bias = &params[2 * l + 1];
        let (k, n) = (w.shape[0], w.shape[1]);
        let mut z = vec![0.0f64; batch * n];
        for r in 0..batch {
            for j in 0..n {
                let mut acc = bias.data[j] as f64;
                for kk in 0..k {
                    acc += a[r * k + kk] * w.data[kk * n + j] as f64;
                }
                z[r * n + j] = acc;
            }
        }
        if l + 1 < layers {
            for v in z.iter_mut() {
                *v = v.max(0.0); // hidden ReLU
            }
            acts.push(z.clone());
            a = z;
        } else {
            return (acts, z); // pre-softmax logits
        }
    }
    unreachable!("spec has at least one layer");
}

/// Full quantized forward: (a) bit-identical across SIMD levels,
/// (b) dense and sparse input paths agree bitwise, (c) the softmax
/// output tracks the f32 oracle within the layer-propagated interval
/// bound. The propagation is the exact decomposition
/// `z_q - z = sum_k (a_q - a) * w_hat + a * (w_hat - w)`, so
/// `err_out[j] <= sum_k err_in[k] * |w_hat[k,j]| + |a[k]| * qerr(k,j)`,
/// ReLU is 1-Lipschitz, the f16 round trip adds `|a| / 2^11 + 2^-25`
/// per hidden element, and the softmax Jacobian rows have l1 norm
/// <= 1/2, so `|p_q - p| <= 0.5 * max_j err_logit[j]` plus float slop.
#[test]
fn quantized_forward_level_invariant_and_within_propagated_bound() {
    let _g = SIMD_LOCK.lock().unwrap();
    let levels = supported_simd_levels();
    let mut rng = Rng::new(0x51AE);
    let mut spec = test_ff_spec(96, &[48, 32], 80, 3);
    spec.kind = "predict".to_string();
    spec.opt_slots = 0;
    let state = ModelState::init(&spec, &mut rng);
    let exe = NativeExecution::new(spec.clone()).expect("exe");
    assert!(exe.supports_quantization());
    let q = exe.quantize_params(&state.params).expect("panels");

    // binary-ish sparse profile input, like the serving encoder emits
    let mut x = HostTensor::zeros(&spec.x_shape());
    let mut sb = SparseBatch::new(spec.m_in);
    let mut row = Vec::new();
    for r in 0..spec.batch {
        row.clear();
        let mut pos: Vec<usize> = rng.sample_distinct(spec.m_in, 8);
        pos.sort_unstable();
        for i in pos {
            x.data[r * spec.m_in + i] = 1.0;
            row.push((i as u32, 1.0f32));
        }
        sb.push_row(&row);
    }

    let oracle = exe
        .predict(&state.params, &BatchInput::Dense(x.clone()))
        .expect("f32 oracle");
    simd::set_level(Some(SimdLevel::Scalar));
    let want = exe
        .predict_quantized(&q, &BatchInput::Dense(x.clone()))
        .expect("scalar quantized");
    for &l in &levels {
        simd::set_level(Some(l));
        let dense = exe
            .predict_quantized(&q, &BatchInput::Dense(x.clone()))
            .expect("dense quantized");
        assert_eq!(dense.data, want.data,
                   "quantized forward diverged at level {}", l.name());
        let sparse = exe
            .predict_quantized(&q, &BatchInput::Sparse(sb.clone()))
            .expect("sparse quantized");
        assert_eq!(sparse.data, want.data,
                   "sparse input path diverged at level {}", l.name());
    }
    simd::set_level(None);

    // interval propagation against the f64 reference activations
    let (acts, _) = naive_forward(&state.params, &x.data, spec.batch);
    let whats: Vec<Option<Vec<f32>>> = q.tensors.iter()
        .map(|t| match t {
            QTensor::Q8(p) => Some(p.dequantize()),
            QTensor::F32(_) => None,
        })
        .collect();
    let layers = state.params.len() / 2;
    for r in 0..spec.batch {
        let mut a: Vec<f64> = x.data[r * spec.m_in..(r + 1) * spec.m_in]
            .iter().map(|&v| v as f64).collect();
        let mut err = vec![0.0f64; spec.m_in];
        for l in 0..layers {
            let QTensor::Q8(p) = &q.tensors[2 * l] else {
                panic!("weight slot {} not quantized", 2 * l);
            };
            let what = whats[2 * l].as_ref().unwrap();
            let (k, n) = (p.k, p.n);
            let mut err_out = vec![0.0f64; n];
            for j in 0..n {
                let mut e = 0.0f64;
                for kk in 0..k {
                    e += a[kk].abs() * p.qerr_bound(kk, j) as f64
                        + err[kk] * what[kk * n + j].abs() as f64;
                }
                // slack for the kernels' f32 rounding (both paths)
                err_out[j] = e * 1.01 + 1.0e-4;
            }
            if l + 1 < layers {
                a = acts[l][r * n..(r + 1) * n].to_vec();
                // ReLU is 1-Lipschitz; the f16 round trip of the
                // quantized path's hidden activations adds half an ulp
                for (ej, &aj) in err_out.iter_mut().zip(&a) {
                    *ej += (aj.abs() + *ej) / 2048.0 + 2.0f64.powi(-25);
                }
                err = err_out;
            } else {
                // softmax: Jacobian row l1 <= 1/2
                let zbound: f64 = err_out.iter().cloned()
                    .fold(0.0, f64::max);
                let pbound = 0.5 * zbound + 1.0e-3;
                for j in 0..n {
                    let d = (oracle.data[r * n + j]
                        - want.data[r * n + j]).abs() as f64;
                    assert!(d <= pbound,
                            "row {r} prob {j}: |p_q - p| = {d} exceeds \
                             propagated bound {pbound}");
                }
            }
        }
    }
}

/// The f16 conversion contract on arbitrary finite inputs: round trip
/// within half an ulp (2^-11 relative) on normals, within half the
/// subnormal quantum (2^-25) below the normal range, and saturation to
/// infinity only at the very top of the representable range.
#[test]
fn prop_f16_round_trip_within_half_ulp() {
    check("f16-half-ulp", 0x0F16, 400,
          |rng| rng.next_u64(),
          |&seed| {
              let mut rng = Rng::new(seed);
              let e = rng.below(28) as i32 - 16;
              let x = (rng.normal() as f32) * 2.0f32.powi(e);
              let y = f16_to_f32(f16_from_f32(x));
              let ax = x.abs();
              if !y.is_finite() {
                  return if ax >= 65504.0 {
                      Ok(()) // saturation at the top of the range
                  } else {
                      Err(format!("{x} saturated to {y}"))
                  };
              }
              if y.is_sign_positive() != x.is_sign_positive()
                  && y != 0.0 {
                  return Err(format!("{x} flipped sign to {y}"));
              }
              let bound =
                  (ax * 2.0f32.powi(-11)).max(2.0f32.powi(-25));
              let err = (x - y).abs();
              if err > bound {
                  Err(format!("{x} -> {y}: err {err} > {bound}"))
              } else {
                  Ok(())
              }
          });
}

/// f16 specials, as the serving tier depends on them: NaN survives
/// (never collapsing into the inf encoding), infinities and signed
/// zeros are preserved, and the subnormal floor flushes to zero.
#[test]
fn f16_specials_survive_the_serving_round_trip() {
    assert!(f16_to_f32(f16_from_f32(f32::NAN)).is_nan());
    let h = f16_from_f32(f32::from_bits(0x7f80_0001)); // min payload NaN
    assert!(f16_to_f32(h).is_nan(), "NaN collapsed to {h:#06x}");
    assert_eq!(f16_to_f32(f16_from_f32(f32::INFINITY)), f32::INFINITY);
    assert_eq!(f16_to_f32(f16_from_f32(f32::NEG_INFINITY)),
               f32::NEG_INFINITY);
    assert!(f16_to_f32(f16_from_f32(-0.0)).is_sign_negative());
    assert_eq!(f16_to_f32(f16_from_f32(2.0f32.powi(-24))),
               2.0f32.powi(-24)); // min subnormal is exact
    assert_eq!(f16_to_f32(f16_from_f32(2.0f32.powi(-26))), 0.0);
}
