//! Serving-stack integration tests: correctness under concurrency, the
//! batching policy, stateful recurrent sessions, and graceful shutdown.
//! Runs on whichever backend `Runtime::new` selects — the native backend
//! (sparse serving path) in a fresh checkout, PJRT when artifacts are
//! built with the `xla` feature.

use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use bloomrec::coordinator::{self, DatasetCache, Method, RunSpec};
use bloomrec::data::{Scale, PAD};
use bloomrec::linalg::Precision;
use bloomrec::runtime::{BatchInput, Execution, HostTensor, Runtime,
                        SparseBatch};
use bloomrec::serve::{BatcherConfig, FaultPlan, RecRequest, ServeConfig,
                      ServeError, Server};

struct Fixture {
    rt: Arc<Runtime>,
    predict: bloomrec::runtime::ArtifactSpec,
    state: bloomrec::model::ModelState,
    emb: Arc<dyn bloomrec::embedding::Embedding>,
    ds: Arc<bloomrec::data::Dataset>,
}

fn fixture() -> Option<Fixture> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let rt = Arc::new(Runtime::new(&dir).expect("runtime"));
    let cache = DatasetCache::new();
    let task = rt.manifest.task("bc").expect("task").clone();
    let spec = RunSpec {
        task: task.name.clone(),
        method: Method::Be { k: 4 },
        ratio: 0.2,
        seed: 1,
        scale: Scale::Tiny,
        epochs: Some(1),
    };
    let m = bloomrec::runtime::round_m(task.d, spec.ratio);
    let ds = cache.get(&task, spec.scale, spec.seed);
    let emb: Arc<dyn bloomrec::embedding::Embedding> =
        coordinator::build_embedding(spec.method, &ds, &task, m, spec.seed)
            .expect("embedding")
            .into();
    let train_spec = rt.manifest
        .find(&task.name, "train", "softmax_ce", m).unwrap().clone();
    let predict = rt.manifest
        .find(&task.name, "predict", "softmax_ce", m).unwrap().clone();
    let (state, _) = coordinator::train(
        &rt, &train_spec, &ds, emb.as_ref(),
        &coordinator::TrainConfig { epochs: 1, seed: 1, ..Default::default() })
        .expect("train");
    Some(Fixture { rt, predict, state, emb, ds })
}

/// Ground-truth top-N computed directly (no server, batch of 1).
fn direct_top_n(f: &Fixture, items: &[u32], n: usize) -> Vec<usize> {
    direct_top_n_for(f, &f.state, items, n)
}

/// Ground truth against an explicit weight set — lets the hot-swap
/// tests compare one query under two model generations.
fn direct_top_n_for(f: &Fixture, state: &bloomrec::model::ModelState,
                    items: &[u32], n: usize) -> Vec<usize> {
    let exe = f.rt.load(&f.predict.name).unwrap();
    let mut x = HostTensor::zeros(&f.predict.x_shape());
    f.emb.encode_input(items, &mut x.data[..f.predict.m_in]);
    let mut inputs: Vec<&HostTensor> = state.params.iter().collect();
    inputs.push(&x);
    let out = exe.run(&inputs, &[]).unwrap();
    let mut scores =
        f.emb.decode(&out[0].data[..f.predict.m_out]);
    for &it in items {
        scores[it as usize] = f32::NEG_INFINITY;
    }
    bloomrec::linalg::knn::top_k(&scores, n)
}

#[test]
fn concurrent_requests_match_direct_computation() {
    let Some(f) = fixture() else { return };
    let server = Server::start(
        Arc::clone(&f.rt), f.predict.clone(), f.state.clone(),
        Arc::clone(&f.emb), ServeConfig {
            replicas: 3,
            // this test asserts bit-equality against the f32 direct
            // computation, so pin the tier (the int8 CI leg flips the
            // BLOOMREC_PRECISION default)
            precision: Precision::F32,
            batcher: BatcherConfig {
                max_batch: 16,
                max_wait: Duration::from_millis(1),
            },
            ..ServeConfig::default()
        }).expect("server");

    // submit a wave of concurrent requests over distinct profiles
    let queries: Vec<Vec<u32>> = f.ds.test.iter().take(40)
        .map(|e| e.input_items().to_vec())
        .collect();
    let rxs: Vec<_> = queries.iter()
        .map(|q| server.submit(RecRequest::new(q.clone(), 5)))
        .collect();
    for (q, rx) in queries.iter().zip(rxs) {
        let resp = rx.recv().expect("response");
        let got: Vec<usize> = resp.items.iter().map(|&(i, _)| i).collect();
        let want = direct_top_n(&f, q, 5);
        assert_eq!(got, want, "mismatch for query {q:?}");
        // scores must be descending
        for w in resp.items.windows(2) {
            assert!(w[0].1 >= w[1].1);
        }
        // the user's own items are never recommended
        for (i, _) in &resp.items {
            assert!(!q.contains(&(*i as u32)));
        }
    }
    let snap = server.metrics.snapshot();
    assert_eq!(snap.requests, queries.len() as u64);
    server.shutdown();
}

#[test]
fn batching_actually_batches_under_load() {
    let Some(f) = fixture() else { return };
    let server = Server::start(
        Arc::clone(&f.rt), f.predict.clone(), f.state.clone(),
        Arc::clone(&f.emb), ServeConfig {
            replicas: 1,
            batcher: BatcherConfig {
                max_batch: 32,
                max_wait: Duration::from_millis(5),
            },
            ..ServeConfig::default()
        }).expect("server");
    let rxs: Vec<_> = (0..200)
        .map(|i| {
            let ex = &f.ds.test[i % f.ds.test.len()];
            server.submit(RecRequest::new(ex.input_items().to_vec(), 3))
        })
        .collect();
    for rx in rxs {
        rx.recv().expect("response");
    }
    let snap = server.metrics.snapshot();
    assert_eq!(snap.requests, 200);
    assert!(snap.batches < 200,
            "no batching happened: {} batches", snap.batches);
    assert!(snap.mean_batch_fill > 1.0 / 32.0);
    server.shutdown();
}

#[test]
fn native_serving_path_is_sparse() {
    let Some(f) = fixture() else { return };
    let exe = f.rt.load(&f.predict.name).expect("load");
    // the native backend must expose sparse input support, so the server
    // never materializes a dense [batch, m_in] tensor on its hot path;
    // PJRT (when active) is allowed to densify behind the boundary
    if f.rt.backend_name() == "native" {
        assert!(exe.supports_sparse_input());
    }
    // ...and the Bloom serving embedding must produce sparse rows
    let mut row = Vec::new();
    assert!(f.emb.encode_input_sparse(&[1, 2, 3], &mut row));
    assert!(!row.is_empty());
    assert!(row.windows(2).all(|w| w[0].0 < w[1].0), "sorted, unique");
}

/// A trained recurrent (yc / GRU) serving fixture on the native backend.
fn recurrent_fixture() -> Option<Fixture> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let rt = Arc::new(Runtime::new(&dir).expect("runtime"));
    if rt.backend_name() != "native" {
        eprintln!("session serving needs the native step interpreter, \
                   skipping on '{}'", rt.backend_name());
        return None;
    }
    let cache = DatasetCache::new();
    let task = rt.manifest.task("yc").expect("task").clone();
    let spec = RunSpec {
        task: task.name.clone(),
        method: Method::Be { k: 4 },
        ratio: 0.1,
        seed: 9,
        scale: Scale::Tiny,
        epochs: Some(1),
    };
    let m = bloomrec::runtime::round_m(task.d, spec.ratio);
    let ds = cache.get(&task, spec.scale, spec.seed);
    let emb: Arc<dyn bloomrec::embedding::Embedding> =
        coordinator::build_embedding(spec.method, &ds, &task, m, spec.seed)
            .expect("embedding")
            .into();
    let train_spec = rt.manifest
        .find(&task.name, "train", "softmax_ce", m).unwrap().clone();
    let predict = rt.manifest
        .find(&task.name, "predict", "softmax_ce", m).unwrap().clone();
    let (state, _) = coordinator::train(
        &rt, &train_spec, &ds, emb.as_ref(),
        &coordinator::TrainConfig { epochs: 1, seed: 9, ..Default::default() })
        .expect("train");
    Some(Fixture { rt, predict, state, emb, ds })
}

/// Replaying a session click-by-click through the server (same session
/// id, one item per request) must end at exactly the state/ranking the
/// public step API produces — the hidden state survives across requests.
#[test]
fn recurrent_session_serving_matches_direct_steps() {
    let Some(f) = recurrent_fixture() else { return };
    let server = Server::start(
        Arc::clone(&f.rt), f.predict.clone(), f.state.clone(),
        Arc::clone(&f.emb), ServeConfig {
            replicas: 2,
            batcher: BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(1),
            },
            ..ServeConfig::default()
        }).expect("server");

    let items: Vec<u32> = f.ds.test.iter()
        .find_map(|e| {
            let v: Vec<u32> = e.input_items().iter().copied()
                .filter(|&i| i != PAD).collect();
            (v.len() >= 3).then_some(v)
        })
        .expect("a session with >= 3 clicks");

    let mut last_resp = None;
    for &click in &items {
        last_resp =
            Some(server.recommend(RecRequest::session(42, vec![click], 5)));
    }
    assert_eq!(server.session_count(), 1, "one live session cached");

    // ground truth via the public stateful API
    let exe = f.rt.load(&f.predict.name).expect("load");
    let mut hs = exe.begin_state(1).expect("state");
    let mut scratch = Vec::new();
    for &click in &items {
        let mut sb = SparseBatch::new(f.predict.m_in);
        assert!(f.emb.encode_input_sparse(&[click], &mut scratch));
        sb.push_row(&scratch);
        exe.step(&f.state.params, &mut hs, &BatchInput::Sparse(sb))
            .expect("step");
    }
    let probs = exe.readout(&f.state.params, &hs).expect("readout");
    let mut scores = f.emb.decode(&probs.data);
    // the server tracks the session's full click history for the top-N
    // protocol, so every click of the session is excluded
    for &click in &items {
        scores[click as usize] = f32::NEG_INFINITY;
    }
    let want = bloomrec::linalg::knn::top_k(&scores, 5);
    let got: Vec<usize> =
        last_resp.unwrap().items.iter().map(|&(i, _)| i).collect();
    assert_eq!(got, want, "session replay diverged from direct steps");
    // recommended items never include any click from the session
    for i in &got {
        assert!(!items.contains(&(*i as u32)),
                "recommended an already-clicked item");
    }

    // a request without a session id is stateless on the same server
    let resp = server.recommend(RecRequest::new(items.clone(), 5));
    assert_eq!(resp.items.len(), 5);
    assert_eq!(server.session_count(), 1, "stateless requests not cached");
    server.shutdown();
}

/// Many concurrent sessions replayed through the micro-batching
/// scheduler (which advances a flush's sessions with ONE batched step
/// per click-round) must each end at exactly the ranking their own
/// sequential step replay produces — batched rows are independent.
/// Sessions have different lengths, so flushes are ragged: sessions
/// join and leave rounds mid-stream.
#[test]
fn concurrent_sessions_match_sequential_replay() {
    let Some(f) = recurrent_fixture() else { return };
    let exe = f.rt.load(&f.predict.name).expect("load");
    assert!(exe.supports_batched_stepping(),
            "native recurrent execution must batch-step");
    let server = Server::start(
        Arc::clone(&f.rt), f.predict.clone(), f.state.clone(),
        Arc::clone(&f.emb), ServeConfig {
            replicas: 1, // one worker => concurrent submits share a flush
            batcher: BatcherConfig {
                max_batch: 16,
                max_wait: Duration::from_millis(5),
            },
            ..ServeConfig::default()
        }).expect("server");

    // distinct sessions with RAGGED lengths (1..=4 clicks)
    let sessions: Vec<(u64, Vec<u32>)> = f.ds.test.iter()
        .filter_map(|e| {
            let v: Vec<u32> = e.input_items().iter().copied()
                .filter(|&i| i != PAD).collect();
            (!v.is_empty()).then_some(v)
        })
        .take(12)
        .enumerate()
        .map(|(s, v)| {
            let len = 1 + s % 4;
            (1000 + s as u64, v[..len.min(v.len())].to_vec())
        })
        .collect();

    // submit every session's whole click list as ONE session request,
    // all concurrently — the single worker flushes them together and
    // advances the pack round by round
    let waiting: Vec<_> = sessions.iter()
        .map(|(id, clicks)| {
            server.submit(RecRequest::session(*id, clicks.clone(), 5))
        })
        .collect();
    let responses: Vec<_> =
        waiting.into_iter().map(|rx| rx.recv().expect("resp")).collect();
    assert_eq!(server.session_count(), sessions.len());

    // ground truth: sequential single-session stepping per session
    let mut scratch = Vec::new();
    for ((_, clicks), resp) in sessions.iter().zip(&responses) {
        let mut hs = exe.begin_state(1).expect("state");
        for &click in clicks {
            let mut sb = SparseBatch::new(f.predict.m_in);
            assert!(f.emb.encode_input_sparse(&[click], &mut scratch));
            sb.push_row(&scratch);
            exe.step(&f.state.params, &mut hs, &BatchInput::Sparse(sb))
                .expect("step");
        }
        let probs = exe.readout(&f.state.params, &hs).expect("readout");
        let mut scores = f.emb.decode(&probs.data);
        for &click in clicks {
            scores[click as usize] = f32::NEG_INFINITY;
        }
        let want = bloomrec::linalg::knn::top_k(&scores, 5);
        let got: Vec<usize> =
            resp.items.iter().map(|&(i, _)| i).collect();
        assert_eq!(got, want, "session {clicks:?} diverged from \
                               sequential replay");
    }
    server.shutdown();
}

/// `try_submit` enforces `queue_cap`: admissions beyond the bound are
/// rejected, a rejection does not leak its in-flight reservation, and
/// capacity frees up again once responses drain. The batcher's
/// `max_wait` keeps the worker holding the flush long enough for the
/// over-cap attempt to be deterministic.
#[test]
fn try_submit_sheds_load_beyond_queue_cap() {
    let Some(f) = fixture() else { return };
    let server = Server::start(
        Arc::clone(&f.rt), f.predict.clone(), f.state.clone(),
        Arc::clone(&f.emb), ServeConfig {
            replicas: 1,
            queue_cap: 1,
            batcher: BatcherConfig {
                max_batch: 64, // never fills -> flush only on deadline
                max_wait: Duration::from_millis(500),
            },
            ..ServeConfig::default()
        }).expect("server");
    let items = f.ds.test[0].input_items().to_vec();

    // slot 1 admitted; the worker sits on it until the 500 ms deadline
    let rx = server.try_submit(RecRequest::new(items.clone(), 3))
        .expect("first request admitted");
    assert_eq!(server.pending(), 1);
    // over the cap while the first is in flight: shed, twice, with the
    // typed error (the second attempt also proves the first rejection
    // gave its reservation back instead of wedging the counter)
    for _ in 0..2 {
        let err = server.try_submit(RecRequest::new(items.clone(), 3))
            .expect_err("over queue_cap must shed");
        assert!(matches!(err, ServeError::QueueFull), "{err}");
    }
    assert_eq!(server.pending(), 1, "rejections must not leak slots");
    assert_eq!(server.metrics.snapshot().queue_full_rejections, 2,
               "each shed admission counts exactly once");

    // once the flush drains, capacity is available again
    rx.recv().expect("response");
    while server.pending() > 0 {
        std::thread::sleep(Duration::from_millis(1));
    }
    let rx = server.try_submit(RecRequest::new(items, 3))
        .expect("capacity freed after drain");
    rx.recv().expect("response");
    server.shutdown();
}

/// Forcing the candidate-pruned decode strategy through `ServeConfig`
/// must keep responses equal to the exhaustive oracle whenever the
/// candidate cap covers the catalog (the exactness contract), and the
/// decode counters must show the pruned tier was exercised.
#[test]
fn pruned_decode_strategy_serves_and_counts() {
    use bloomrec::bloom::DecodeStrategy;
    let Some(f) = fixture() else { return };
    let d_cap = 1 << 20; // >= any tiny-scale catalog -> exact fallback
    let server = Server::start(
        Arc::clone(&f.rt), f.predict.clone(), f.state.clone(),
        Arc::clone(&f.emb), ServeConfig {
            replicas: 1,
            precision: Precision::F32, // bit-equality vs the f32 oracle
            batcher: BatcherConfig {
                max_batch: 16,
                max_wait: Duration::from_millis(1),
            },
            decode: Some(DecodeStrategy::Pruned {
                top_positions: 64,
                max_candidates: d_cap,
            }),
            ..ServeConfig::default()
        }).expect("server");
    let queries: Vec<Vec<u32>> = f.ds.test.iter().take(20)
        .map(|e| e.input_items().to_vec())
        .collect();
    let rxs: Vec<_> = queries.iter()
        .map(|q| server.submit(RecRequest::new(q.clone(), 5)))
        .collect();
    for (q, rx) in queries.iter().zip(rxs) {
        let resp = rx.recv().expect("response");
        let got: Vec<usize> =
            resp.items.iter().map(|&(i, _)| i).collect();
        assert_eq!(got, direct_top_n(&f, q, 5),
                   "pruned (exact-fallback) response diverged for {q:?}");
    }
    let snap = server.metrics.snapshot();
    assert_eq!(snap.pruned_requests, queries.len() as u64,
               "every decode should have taken the pruned tier");
    assert_eq!(snap.decode_fallbacks, queries.len() as u64,
               "cap >= d must report the exact fallback");
    assert!(snap.decode_scored >= snap.pruned_requests);
    assert!(snap.decode_catalog >= snap.decode_scored);
    server.shutdown();
}

/// Swap a packed artifact under live stateless load. Every response —
/// including those straddling the swap — must match exactly one model
/// generation's direct computation (no lost and no mixed-model
/// responses), requests submitted after the swap must deterministically
/// see the new weights, a corrupt artifact must be rejected without
/// disturbing serving, and the whole roll must be visible in the
/// metrics counters.
#[test]
fn hot_swap_under_load_is_atomic_and_observable() {
    use bloomrec::artifact;
    use bloomrec::model::ModelState;
    use bloomrec::util::rng::Rng;

    let Some(f) = fixture() else { return };
    // model B: same architecture, fresh random weights — rankings
    // differ from the trained model A on essentially every query
    let state_b = ModelState::init(&f.predict, &mut Rng::new(4242));
    let dir = std::env::temp_dir().join(format!(
        "bloomrec_swap_ff_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let bloom = f.emb.as_bloom().expect("serving embedding is Bloom");
    artifact::pack(&dir, &f.predict, &state_b, Some(bloom)).expect("pack");

    let server = Server::start(
        Arc::clone(&f.rt), f.predict.clone(), f.state.clone(),
        Arc::clone(&f.emb), ServeConfig {
            replicas: 2,
            precision: Precision::F32, // bit-equality vs the f32 oracle
            batcher: BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(1),
            },
            ..ServeConfig::default()
        }).expect("server");

    let queries: Vec<Vec<u32>> = f.ds.test.iter().take(30)
        .map(|e| e.input_items().to_vec())
        .collect();
    let want_a: Vec<Vec<usize>> = queries.iter()
        .map(|q| direct_top_n_for(&f, &f.state, q, 5)).collect();
    let want_b: Vec<Vec<usize>> = queries.iter()
        .map(|q| direct_top_n_for(&f, &state_b, q, 5)).collect();
    assert!(want_a != want_b,
            "fresh random weights must rank differently somewhere");

    // wave 1: settled on model A
    let rxs: Vec<_> = queries.iter()
        .map(|q| server.submit(RecRequest::new(q.clone(), 5)))
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let got: Vec<usize> =
            rx.recv().expect("resp").items.iter().map(|&(i, _)| i).collect();
        assert_eq!(got, want_a[i], "pre-swap response must be model A");
    }
    let snap = server.metrics.snapshot();
    assert_eq!((snap.swaps_applied, snap.swaps_rejected), (0, 0));

    // straddle: requests in flight on both sides of the swap
    let before: Vec<_> = queries.iter()
        .map(|q| server.submit(RecRequest::new(q.clone(), 5)))
        .collect();
    let report = server.swap_artifact(&dir).expect("swap accepted");
    assert_eq!(report.spec_name, f.predict.name);
    assert_eq!(report.sessions_drained, 0, "stateless load: no sessions");
    let after: Vec<_> = queries.iter()
        .map(|q| server.submit(RecRequest::new(q.clone(), 5)))
        .collect();
    for (i, rx) in before.into_iter().enumerate() {
        let got: Vec<usize> =
            rx.recv().expect("resp").items.iter().map(|&(i, _)| i).collect();
        assert!(got == want_a[i] || got == want_b[i],
                "straddling response mixed models for query {i}: {got:?}");
    }
    for (i, rx) in after.into_iter().enumerate() {
        let got: Vec<usize> =
            rx.recv().expect("resp").items.iter().map(|&(i, _)| i).collect();
        // the flush serving this job was collected after the swap, so
        // it pinned the new generation — deterministically model B
        assert_eq!(got, want_b[i], "post-swap response must be model B");
    }

    // settled on model B
    let rxs: Vec<_> = queries.iter()
        .map(|q| server.submit(RecRequest::new(q.clone(), 5)))
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let got: Vec<usize> =
            rx.recv().expect("resp").items.iter().map(|&(i, _)| i).collect();
        assert_eq!(got, want_b[i]);
    }

    // a corrupt artifact is rejected and serving stays on model B
    let p = dir.join(artifact::PAYLOAD_FILE);
    let mut bytes = std::fs::read(&p).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x40;
    std::fs::write(&p, &bytes).unwrap();
    let err = server.swap_artifact(&dir).unwrap_err();
    assert!(err.to_string().contains("checksum"), "{err}");
    let got: Vec<usize> = server
        .recommend(RecRequest::new(queries[0].clone(), 5))
        .items.iter().map(|&(i, _)| i).collect();
    assert_eq!(got, want_b[0], "rejected swap must not disturb serving");

    let snap = server.metrics.snapshot();
    assert_eq!(snap.swaps_applied, 1);
    assert_eq!(snap.swaps_rejected, 1);
    assert_eq!(snap.sessions_drained, 0);
    server.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Swapping under a recurrent server drains the per-session hidden
/// states: the counters report the drain, and a drained session
/// restarts fresh on the new generation (identical to a brand-new
/// session) instead of resuming an old-model hidden state.
#[test]
fn hot_swap_drains_recurrent_sessions() {
    use bloomrec::artifact;

    let Some(f) = recurrent_fixture() else { return };
    let dir = std::env::temp_dir().join(format!(
        "bloomrec_swap_rnn_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    // pack the SAME weights: the swap still drains sessions (the old
    // hidden states are not portable across generations by contract)
    let bloom = f.emb.as_bloom().expect("serving embedding is Bloom");
    artifact::pack(&dir, &f.predict, &f.state, Some(bloom)).expect("pack");

    let server = Server::start(
        Arc::clone(&f.rt), f.predict.clone(), f.state.clone(),
        Arc::clone(&f.emb), ServeConfig {
            replicas: 2,
            batcher: BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(1),
            },
            ..ServeConfig::default()
        }).expect("server");

    let clicks: Vec<u32> = f.ds.test.iter()
        .flat_map(|e| e.input_items().iter().copied())
        .filter(|&i| i != PAD)
        .take(6)
        .collect();
    assert_eq!(clicks.len(), 6, "need 6 clicks from the tiny split");
    for (sid, &click) in clicks.iter().enumerate() {
        server.recommend(RecRequest::session(sid as u64 + 1,
                                             vec![click], 5));
    }
    assert_eq!(server.session_count(), 6);

    let report = server.swap_artifact(&dir).expect("swap accepted");
    assert_eq!(report.sessions_drained, 6);
    assert_eq!(server.session_count(), 0, "cache drained at the swap");

    // session 1 "continues" after the drain — it must behave exactly
    // like a brand-new session on the new generation
    let cont = server.recommend(RecRequest::session(1, vec![clicks[3]], 5));
    let fresh = server.recommend(RecRequest::session(99, vec![clicks[3]], 5));
    assert_eq!(cont.items, fresh.items,
               "drained session must restart fresh, not resume old state");

    let snap = server.metrics.snapshot();
    assert_eq!(snap.swaps_applied, 1);
    assert_eq!(snap.swaps_rejected, 0);
    assert_eq!(snap.sessions_drained, 6);
    server.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// The opt-in int8 tier end to end through the server: quantized
/// serving is NOT bit-identical to f32 (by contract), but it must be
/// bit-identical to the direct quantized computation — the tier is
/// deterministic within itself across batching, replicas, and the
/// server's sparse input path.
#[test]
fn int8_precision_tier_serves_deterministically() {
    let Some(f) = fixture() else { return };
    if f.rt.backend_name() != "native" {
        eprintln!("int8 tier is native-only, skipping on '{}'",
                  f.rt.backend_name());
        return;
    }
    let server = Server::start(
        Arc::clone(&f.rt), f.predict.clone(), f.state.clone(),
        Arc::clone(&f.emb), ServeConfig {
            replicas: 2,
            precision: Precision::Int8,
            batcher: BatcherConfig {
                max_batch: 16,
                max_wait: Duration::from_millis(1),
            },
            ..ServeConfig::default()
        }).expect("server");

    // direct quantized oracle: same panels the router derives (the
    // quantizer is deterministic), dense input (the server's sparse
    // gather is bit-identical to the dense path by construction)
    let exe = f.rt.load_spec(&f.predict).expect("exe");
    let q = exe.quantize_params(&f.state.params).expect("panels");

    let queries: Vec<Vec<u32>> = f.ds.test.iter().take(20)
        .map(|e| e.input_items().to_vec())
        .collect();
    let rxs: Vec<_> = queries.iter()
        .map(|qr| server.submit(RecRequest::new(qr.clone(), 5)))
        .collect();
    for (items, rx) in queries.iter().zip(rxs) {
        let resp = rx.recv().expect("response");
        assert!(resp.error.is_none(), "int8 serving failed: {:?}",
                resp.error);
        let mut x = HostTensor::zeros(&f.predict.x_shape());
        f.emb.encode_input(items, &mut x.data[..f.predict.m_in]);
        let probs = exe.predict_quantized(&q, &BatchInput::Dense(x))
            .expect("quantized predict");
        let mut scores = f.emb.decode(&probs.data[..f.predict.m_out]);
        for &it in items {
            scores[it as usize] = f32::NEG_INFINITY;
        }
        let want = bloomrec::linalg::knn::top_k(&scores, 5);
        let got: Vec<usize> =
            resp.items.iter().map(|&(i, _)| i).collect();
        assert_eq!(got, want,
                   "int8 serving diverged from the direct quantized \
                    computation for {items:?}");
        for w in resp.items.windows(2) {
            assert!(w[0].1 >= w[1].1, "scores must be descending");
        }
        for (i, _) in &resp.items {
            assert!(!items.contains(&(*i as u32)),
                    "recommended one of the user's own items");
        }
    }
    server.shutdown();
}

#[test]
fn shutdown_drains_and_joins() {
    let Some(f) = fixture() else { return };
    let server = Server::start(
        Arc::clone(&f.rt), f.predict.clone(), f.state.clone(),
        Arc::clone(&f.emb), ServeConfig::default()).expect("server");
    let ex = &f.ds.test[0];
    let rx = server.submit(RecRequest::new(ex.input_items().to_vec(), 3));
    rx.recv().expect("response before shutdown");
    server.shutdown(); // must not hang or panic
}

/// The zero-drop half of the shutdown contract: every request admitted
/// before `shutdown()` gets a real response, even when shutdown lands
/// while the whole backlog is still queued behind a slow batching
/// deadline. (The workers drain their queues before joining.)
#[test]
fn shutdown_answers_every_admitted_request() {
    let Some(f) = fixture() else { return };
    let server = Server::start(
        Arc::clone(&f.rt), f.predict.clone(), f.state.clone(),
        Arc::clone(&f.emb), ServeConfig {
            replicas: 2,
            precision: Precision::F32, // bit-equality vs the f32 oracle
            batcher: BatcherConfig {
                max_batch: 64,
                // long deadline: the backlog is still queued when
                // shutdown arrives (channel close short-circuits it)
                max_wait: Duration::from_millis(400),
            },
            ..ServeConfig::default()
        }).expect("server");
    let items = f.ds.test[0].input_items().to_vec();
    let want = direct_top_n(&f, &items, 3);
    let rxs: Vec<_> = (0..40)
        .map(|_| server.submit(RecRequest::new(items.clone(), 3)))
        .collect();
    server.shutdown(); // drains the 40 queued jobs before joining
    for rx in rxs {
        let resp = rx.recv()
            .expect("admitted request answered across shutdown");
        assert!(resp.error.is_none(), "drained response errored");
        let got: Vec<usize> =
            resp.items.iter().map(|&(i, _)| i).collect();
        assert_eq!(got, want);
    }
}

/// Affinity property: with N replicas and randomized session ids, every
/// session's hidden state is cached on exactly its home replica
/// (`Router::replica_for`), across multiple click waves — states never
/// migrate and shards never double-cache.
#[test]
fn sessions_stay_on_their_home_replica() {
    use bloomrec::util::rng::Rng;
    let Some(f) = recurrent_fixture() else { return };
    let server = Server::start(
        Arc::clone(&f.rt), f.predict.clone(), f.state.clone(),
        Arc::clone(&f.emb), ServeConfig {
            replicas: 4,
            high_water: usize::MAX, // never degrade in this test
            batcher: BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(1),
            },
            ..ServeConfig::default()
        }).expect("server");

    // randomized ids over the full u64 space, distinct
    let mut rng = Rng::new(0xA11F);
    let mut ids: Vec<u64> = (0..16).map(|_| rng.next_u64()).collect();
    ids.sort_unstable();
    ids.dedup();
    let sessions: Vec<(u64, Vec<u32>)> = f.ds.test.iter()
        .filter_map(|e| {
            let v: Vec<u32> = e.input_items().iter().copied()
                .filter(|&i| i != PAD).collect();
            (!v.is_empty()).then_some(v)
        })
        .take(ids.len())
        .zip(ids.iter().copied())
        .map(|(clicks, id)| (id, clicks))
        .collect();

    // two click waves per session, concurrent across sessions
    for wave in 0..2 {
        let rxs: Vec<_> = sessions.iter()
            .map(|(id, clicks)| {
                let click = clicks[wave % clicks.len()];
                server.submit(RecRequest::session(*id, vec![click], 5))
            })
            .collect();
        for rx in rxs {
            let resp = rx.recv().expect("resp");
            assert!(!resp.degraded, "under high_water, never degraded");
        }
        // after every wave: each session cached exactly on its home
        for (id, _) in &sessions {
            let home = server.router().replica_for(*id);
            assert_eq!(server.router().session_replica(*id), Some(home),
                       "session {id} strayed from its home replica");
        }
        let counts = server.router().session_counts();
        assert_eq!(counts.len(), 4);
        assert_eq!(counts.iter().sum::<usize>(), sessions.len(),
                   "shards double-cached a session: {counts:?}");
    }
    server.shutdown();
}

/// Forced overload (`high_water: 0`): every stateful request is
/// admitted, answered through the degraded stateless path (flagged,
/// counted, bit-identical to a stateless request for the same items),
/// and nothing is cached or dropped. Stateless traffic is untouched.
#[test]
fn overload_degrades_stateful_requests_instead_of_dropping() {
    let Some(f) = recurrent_fixture() else { return };
    let server = Server::start(
        Arc::clone(&f.rt), f.predict.clone(), f.state.clone(),
        Arc::clone(&f.emb), ServeConfig {
            replicas: 2,
            high_water: 0, // every replica is "over water" from job 1
            batcher: BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(1),
            },
            ..ServeConfig::default()
        }).expect("server");

    let clicks: Vec<u32> = f.ds.test.iter()
        .flat_map(|e| e.input_items().iter().copied())
        .filter(|&i| i != PAD)
        .take(8)
        .collect();
    assert_eq!(clicks.len(), 8);

    for (sid, &click) in clicks.iter().enumerate() {
        let resp = server.recommend(
            RecRequest::session(sid as u64 + 1, vec![click], 5));
        assert!(resp.degraded, "over high water must degrade");
        assert!(resp.error.is_none(), "degraded is answered, not failed");
        // degraded == the stateless answer for the same item window
        let stateless =
            server.recommend(RecRequest::new(vec![click], 5));
        assert!(!stateless.degraded,
                "stateless requests are never marked degraded");
        assert_eq!(resp.items, stateless.items,
                   "degraded response must equal the stateless path");
    }
    assert_eq!(server.session_count(), 0,
               "degraded requests must not populate session caches");
    let snap = server.metrics.snapshot();
    assert_eq!(snap.degraded_responses, clicks.len() as u64,
               "exactly one degraded tick per stateful request");
    assert_eq!(snap.failed_responses, 0);
    assert_eq!(snap.requests, 2 * clicks.len() as u64);
    assert_eq!(snap.queue_depths.len(), 2);
    server.shutdown();
}

/// One `swap_artifact` call rolls all replicas: under continuous
/// concurrent load on a 4-replica tier, every response matches exactly
/// one generation (never a mix), traffic after the call settles on the
/// new weights everywhere, and the roll reports as ONE applied swap.
#[test]
fn swap_rolls_every_replica_under_concurrent_load() {
    use bloomrec::artifact;
    use bloomrec::model::ModelState;
    use bloomrec::util::rng::Rng;

    let Some(f) = fixture() else { return };
    let state_b = ModelState::init(&f.predict, &mut Rng::new(777));
    let dir = std::env::temp_dir().join(format!(
        "bloomrec_swap_roll_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let bloom = f.emb.as_bloom().expect("serving embedding is Bloom");
    artifact::pack(&dir, &f.predict, &state_b, Some(bloom)).expect("pack");

    let server = Server::start(
        Arc::clone(&f.rt), f.predict.clone(), f.state.clone(),
        Arc::clone(&f.emb), ServeConfig {
            replicas: 4,
            precision: Precision::F32, // bit-equality vs the f32 oracle
            batcher: BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(1),
            },
            ..ServeConfig::default()
        }).expect("server");

    let queries: Vec<Vec<u32>> = f.ds.test.iter().take(12)
        .map(|e| e.input_items().to_vec())
        .collect();
    let want_a: Vec<Vec<usize>> = queries.iter()
        .map(|q| direct_top_n_for(&f, &f.state, q, 5)).collect();
    let want_b: Vec<Vec<usize>> = queries.iter()
        .map(|q| direct_top_n_for(&f, &state_b, q, 5)).collect();
    assert!(want_a != want_b);

    // hammer all replicas from a client thread while the main thread
    // rolls the swap mid-stream
    std::thread::scope(|s| {
        let server = &server;
        let queries = &queries;
        let (want_a, want_b) = (&want_a, &want_b);
        s.spawn(move || {
            for round in 0..30 {
                let rxs: Vec<_> = queries.iter()
                    .map(|q| server.submit(RecRequest::new(q.clone(), 5)))
                    .collect();
                for (i, rx) in rxs.into_iter().enumerate() {
                    let got: Vec<usize> = rx.recv().expect("resp")
                        .items.iter().map(|&(i, _)| i).collect();
                    assert!(got == want_a[i] || got == want_b[i],
                            "round {round} query {i} mixed generations: \
                             {got:?}");
                }
            }
        });
        std::thread::sleep(Duration::from_millis(20));
        server.swap_artifact(&dir).expect("swap accepted");
    });

    // settled: every replica serves model B. Stateless requests go to
    // the shortest queue; an idle tier spreads them round-robin, so 4x
    // the query set touches every replica with high probability
    for _ in 0..4 {
        let rxs: Vec<_> = queries.iter()
            .map(|q| server.submit(RecRequest::new(q.clone(), 5)))
            .collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let got: Vec<usize> = rx.recv().expect("resp")
                .items.iter().map(|&(i, _)| i).collect();
            assert_eq!(got, want_b[i], "a replica kept the old model");
        }
    }
    let snap = server.metrics.snapshot();
    assert_eq!(snap.swaps_applied, 1, "one roll == one applied swap");
    assert_eq!(snap.swaps_rejected, 0);
    server.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// CI load-smoke: two short Zipf load rounds against a 2-replica tier.
/// Zero-drop (completed == sent, failed == 0), live per-replica
/// gauges, and counters that only ever move forward between snapshots.
/// `--ignored`: it sustains wall-clock load, so it runs in its own CI
/// leg rather than inside the unit sweep.
#[test]
#[ignore]
fn load_smoke() {
    use bloomrec::serve::{run_load, LoadConfig};
    use bloomrec::util::rng::Rng;
    let Some(f) = recurrent_fixture() else { return };
    let d = f.ds.d;
    let server = Server::start(
        Arc::clone(&f.rt), f.predict.clone(), f.state.clone(),
        Arc::clone(&f.emb), ServeConfig {
            replicas: 2,
            batcher: BatcherConfig {
                max_batch: 16,
                max_wait: Duration::from_micros(200),
            },
            ..ServeConfig::default()
        }).expect("server");
    let mut rng = Rng::new(11);
    let pool = bloomrec::data::sequences::generate_serve_sessions(
        d, 256, 6, &mut rng);
    let cfg = LoadConfig {
        users: 10_000,
        concurrency: 8,
        duration: Duration::from_millis(400),
        stateful: true,
        ..LoadConfig::default()
    };

    let r1 = run_load(&server, &pool, &cfg);
    assert!(r1.sent > 0, "harness generated no traffic");
    assert_eq!(r1.completed, r1.sent, "dropped responses in round 1");
    assert_eq!(r1.failed, 0);
    let s1 = server.metrics.snapshot();
    assert_eq!(s1.queue_depths.len(), 2);

    let r2 = run_load(&server, &pool, &cfg);
    assert_eq!(r2.completed, r2.sent, "dropped responses in round 2");
    assert_eq!(r2.failed, 0);
    let s2 = server.metrics.snapshot();

    // counters are cumulative and monotone across rounds
    assert!(s2.requests >= s1.requests + r2.sent,
            "requests went backwards: {} then {}", s1.requests,
            s2.requests);
    assert!(s2.batches >= s1.batches);
    assert!(s2.degraded_responses >= s1.degraded_responses);
    assert_eq!(s2.failed_responses, 0);
    server.shutdown();
}

/// Poll a metrics counter until it reaches `want` (the supervisor runs
/// on replica threads, so restarts land asynchronously).
fn wait_for(server: &Server, want: u64, read: fn(
    &bloomrec::serve::MetricsSnapshot) -> u64) -> u64 {
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    loop {
        let got = read(&server.metrics.snapshot());
        if got >= want || std::time::Instant::now() >= deadline {
            return got;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
}

/// Deadline checkout: jobs past their deadline when the batcher hands
/// the flush over are answered `DeadlineExceeded` immediately; jobs
/// with headroom in the SAME flush are served normally — zero-drop
/// either way, with the expiries counted exactly.
#[test]
fn deadlines_expire_queued_requests_at_checkout() {
    let Some(f) = fixture() else { return };
    let server = Server::start(
        Arc::clone(&f.rt), f.predict.clone(), f.state.clone(),
        Arc::clone(&f.emb), ServeConfig {
            replicas: 1,
            precision: Precision::F32, // bit-equality vs the f32 oracle
            // the default deadline expires while the batcher is still
            // waiting for the flush to fill
            default_deadline: Some(Duration::from_millis(20)),
            batcher: BatcherConfig {
                max_batch: 64, // never fills -> flush only on deadline
                max_wait: Duration::from_millis(150),
            },
            ..ServeConfig::default()
        }).expect("server");
    let items = f.ds.test[0].input_items().to_vec();
    let want = direct_top_n(&f, &items, 3);

    // five requests on the 20 ms default deadline plus one with its
    // own 10 s budget, all queued into the same 150 ms flush window
    let doomed: Vec<_> = (0..5)
        .map(|_| server.submit(RecRequest::new(items.clone(), 3)))
        .collect();
    let alive = server.submit(
        RecRequest::new(items.clone(), 3)
            .with_timeout(Duration::from_secs(10)));
    for rx in doomed {
        let resp = rx.recv().expect("expired request still answered");
        assert!(matches!(resp.error, Some(ServeError::DeadlineExceeded)),
                "expected DeadlineExceeded, got {:?}", resp.error);
        assert!(resp.items.is_empty());
    }
    let resp = alive.recv().expect("live request answered");
    assert!(resp.error.is_none(), "{:?}", resp.error);
    let got: Vec<usize> = resp.items.iter().map(|&(i, _)| i).collect();
    assert_eq!(got, want, "surviving job must serve normally");

    let snap = server.metrics.snapshot();
    assert_eq!(snap.deadline_expired, 5, "exact expiry count");
    assert_eq!(snap.failed_responses, 0,
               "expiry is its own bucket, not a failure");
    server.shutdown();
}

/// Inner supervision ring: an injected flush panic answers exactly the
/// checked-out jobs with `ReplicaPanicked` and the SAME loop keeps
/// serving (no restart) — one bad batch is not an outage.
#[test]
fn caught_panic_answers_jobs_and_replica_keeps_serving() {
    let Some(f) = fixture() else { return };
    let plan = FaultPlan::parse("panic:1,panic_budget:1")
        .expect("fault grammar");
    let server = Server::start(
        Arc::clone(&f.rt), f.predict.clone(), f.state.clone(),
        Arc::clone(&f.emb), ServeConfig {
            replicas: 1,
            precision: Precision::F32,
            faults: Some(Arc::new(plan)),
            batcher: BatcherConfig {
                max_batch: 16,
                max_wait: Duration::from_millis(1),
            },
            ..ServeConfig::default()
        }).expect("server");
    let items = f.ds.test[0].input_items().to_vec();
    let want = direct_top_n(&f, &items, 3);

    // flush 1 hits the injected panic (budget 1): answered, not lost
    let resp = server.recommend(RecRequest::new(items.clone(), 3));
    match &resp.error {
        Some(ServeError::ReplicaPanicked(msg)) => {
            assert!(msg.contains("injected flush panic"), "{msg}");
        }
        other => panic!("expected ReplicaPanicked, got {other:?}"),
    }

    // budget spent: the same replica serves the next flush correctly
    let resp = server.recommend(RecRequest::new(items.clone(), 3));
    assert!(resp.error.is_none(), "{:?}", resp.error);
    let got: Vec<usize> = resp.items.iter().map(|&(i, _)| i).collect();
    assert_eq!(got, want);

    let snap = server.metrics.snapshot();
    assert_eq!(snap.failed_responses, 1, "one panicked flush == one \
                                          failed response");
    assert_eq!(snap.replica_restarts, 0,
               "a caught panic must not restart the replica");
    server.shutdown();
}

/// Outer supervision ring: injected FATAL panics escape the flush loop;
/// the supervisor respawns it in place (counted), and — the subtle
/// contract — the respawned replica still CACHES sessions, proving the
/// restart reinstalled its generation under the bumped epoch (a
/// restart that only bumped the epoch would silently disable session
/// caching forever).
#[test]
fn fatal_panic_restarts_replica_and_sessions_still_cache() {
    let Some(f) = recurrent_fixture() else { return };
    let plan = FaultPlan::parse("fatal:1,fatal_budget:2")
        .expect("fault grammar");
    let server = Server::start(
        Arc::clone(&f.rt), f.predict.clone(), f.state.clone(),
        Arc::clone(&f.emb), ServeConfig {
            replicas: 1,
            faults: Some(Arc::new(plan)),
            batcher: BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(1),
            },
            ..ServeConfig::default()
        }).expect("server");

    // the two budgeted fatals fire on the replica's first two ticks
    let restarts = wait_for(&server, 2, |s| s.replica_restarts);
    assert_eq!(restarts, 2, "both budgeted fatals must restart");

    // post-restart: stateful serving works AND the session is cached
    let clicks: Vec<u32> = f.ds.test.iter()
        .flat_map(|e| e.input_items().iter().copied())
        .filter(|&i| i != PAD)
        .take(2)
        .collect();
    assert_eq!(clicks.len(), 2);
    let mut last = None;
    for &click in &clicks {
        let resp = server.recommend(
            RecRequest::session(7, vec![click], 5));
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert_eq!(resp.items.len(), 5);
        last = Some(resp);
    }
    assert_eq!(server.session_count(), 1,
               "respawned replica must cache sessions (generation \
                reinstalled under the bumped epoch)");

    // the cached state is real: click 2 resumed click 1's hidden
    // state, so its ranking equals the direct two-step replay
    let exe = f.rt.load(&f.predict.name).expect("load");
    let mut hs = exe.begin_state(1).expect("state");
    let mut scratch = Vec::new();
    for &click in &clicks {
        let mut sb = SparseBatch::new(f.predict.m_in);
        assert!(f.emb.encode_input_sparse(&[click], &mut scratch));
        sb.push_row(&scratch);
        exe.step(&f.state.params, &mut hs, &BatchInput::Sparse(sb))
            .expect("step");
    }
    let probs = exe.readout(&f.state.params, &hs).expect("readout");
    let mut scores = f.emb.decode(&probs.data);
    for &click in &clicks {
        scores[click as usize] = f32::NEG_INFINITY;
    }
    let want = bloomrec::linalg::knn::top_k(&scores, 5);
    let got: Vec<usize> = last.unwrap()
        .items.iter().map(|&(i, _)| i).collect();
    assert_eq!(got, want,
               "session state across restarts diverged from replay");
    server.shutdown();
}

/// Transient swap failures retry with backoff inside ONE call: two
/// injected failures burn two retries, the third attempt lands, and
/// the call reports one applied swap (retries counted, no rejection).
#[test]
fn swap_retries_recover_from_transient_failures() {
    use bloomrec::artifact;
    use bloomrec::model::ModelState;
    use bloomrec::util::rng::Rng;

    let Some(f) = fixture() else { return };
    let state_b = ModelState::init(&f.predict, &mut Rng::new(31));
    let dir = std::env::temp_dir().join(format!(
        "bloomrec_swap_retry_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let bloom = f.emb.as_bloom().expect("serving embedding is Bloom");
    artifact::pack(&dir, &f.predict, &state_b, Some(bloom))
        .expect("pack");

    let server = Server::start(
        Arc::clone(&f.rt), f.predict.clone(), f.state.clone(),
        Arc::clone(&f.emb), ServeConfig {
            replicas: 2,
            swap_retries: 2,
            swap_backoff: Duration::from_millis(1),
            ..ServeConfig::default()
        }).expect("server");
    let plan = FaultPlan::default().with_swap_fails(2);
    server.install_faults(Some(Arc::new(plan)));

    let report = server.swap_artifact(&dir)
        .expect("retries must absorb both transient failures");
    assert!(!report.tripped);
    assert_eq!(report.spec_name, f.predict.name);

    let snap = server.metrics.snapshot();
    assert_eq!(snap.swap_retries, 2, "exactly two retries burned");
    assert_eq!(snap.swaps_applied, 1);
    assert_eq!(snap.swaps_rejected, 0,
               "a call that eventually lands is not a rejection");
    assert_eq!(snap.breaker_trips, 0);
    server.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// The swap circuit breaker: K consecutive failed calls trip it, a
/// tripped call pins the old generation (`SwapReport::tripped`) without
/// attempting, and `reset_swap_breaker` re-arms the path.
#[test]
fn swap_breaker_trips_pins_generation_and_resets() {
    use bloomrec::artifact;
    use bloomrec::model::ModelState;
    use bloomrec::util::rng::Rng;

    let Some(f) = fixture() else { return };
    let state_b = ModelState::init(&f.predict, &mut Rng::new(55));
    let dir = std::env::temp_dir().join(format!(
        "bloomrec_swap_breaker_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let bloom = f.emb.as_bloom().expect("serving embedding is Bloom");
    artifact::pack(&dir, &f.predict, &state_b, Some(bloom))
        .expect("pack");

    let server = Server::start(
        Arc::clone(&f.rt), f.predict.clone(), f.state.clone(),
        Arc::clone(&f.emb), ServeConfig {
            replicas: 1,
            precision: Precision::F32,
            swap_retries: 0, // every injected failure fails its call
            breaker_threshold: 2,
            ..ServeConfig::default()
        }).expect("server");
    server.install_faults(
        Some(Arc::new(FaultPlan::default().with_swap_fails(2))));

    let items = f.ds.test[0].input_items().to_vec();
    let want_a = direct_top_n(&f, &items, 5);

    // two failed calls -> breaker trips on the second
    for _ in 0..2 {
        server.swap_artifact(&dir)
            .expect_err("injected failure must fail the call");
    }
    // tripped: the call is a no-op success pinning the old generation
    let report = server.swap_artifact(&dir).expect("tripped report");
    assert!(report.tripped, "breaker must report the trip");
    assert_eq!(report.sessions_drained, 0);
    let got: Vec<usize> = server
        .recommend(RecRequest::new(items.clone(), 5))
        .items.iter().map(|&(i, _)| i).collect();
    assert_eq!(got, want_a, "tripped swap must leave model A serving");

    let snap = server.metrics.snapshot();
    assert_eq!(snap.swaps_rejected, 2);
    assert_eq!(snap.breaker_trips, 1, "one trip, counted once");
    assert_eq!(snap.swaps_applied, 0);

    // re-arm: the injected failures are spent, so the swap now lands
    server.reset_swap_breaker();
    let report = server.swap_artifact(&dir).expect("swap after reset");
    assert!(!report.tripped);
    let want_b = direct_top_n_for(&f, &state_b, &items, 5);
    let got: Vec<usize> = server
        .recommend(RecRequest::new(items.clone(), 5))
        .items.iter().map(|&(i, _)| i).collect();
    assert_eq!(got, want_b, "post-reset swap must install model B");
    assert_eq!(server.metrics.snapshot().swaps_applied, 1);
    server.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Race leg: `shutdown()` concurrent with `swap_artifact()` and a
/// client wave. Every admitted request must resolve — a real response
/// on exactly one generation, or a clean `ShuttingDown` refusal —
/// with no hangs, no drops, and no mixed-generation rankings.
#[test]
fn shutdown_racing_swap_answers_everything() {
    use bloomrec::artifact;
    use bloomrec::model::ModelState;
    use bloomrec::util::rng::Rng;

    let Some(f) = fixture() else { return };
    let state_b = ModelState::init(&f.predict, &mut Rng::new(91));
    let dir = std::env::temp_dir().join(format!(
        "bloomrec_swap_race_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let bloom = f.emb.as_bloom().expect("serving embedding is Bloom");
    artifact::pack(&dir, &f.predict, &state_b, Some(bloom))
        .expect("pack");

    let server = Server::start(
        Arc::clone(&f.rt), f.predict.clone(), f.state.clone(),
        Arc::clone(&f.emb), ServeConfig {
            replicas: 2,
            precision: Precision::F32,
            batcher: BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(1),
            },
            ..ServeConfig::default()
        }).expect("server");

    let queries: Vec<Vec<u32>> = f.ds.test.iter().take(10)
        .map(|e| e.input_items().to_vec())
        .collect();
    let want_a: Vec<Vec<usize>> = queries.iter()
        .map(|q| direct_top_n_for(&f, &f.state, q, 5)).collect();
    let want_b: Vec<Vec<usize>> = queries.iter()
        .map(|q| direct_top_n_for(&f, &state_b, q, 5)).collect();

    std::thread::scope(|s| {
        let server = &server;
        let dir = &dir;
        let (queries, want_a, want_b) = (&queries, &want_a, &want_b);
        s.spawn(move || {
            for round in 0..20 {
                let rxs: Vec<_> = queries.iter()
                    .map(|q| server.submit(RecRequest::new(q.clone(), 5)))
                    .collect();
                for (i, rx) in rxs.into_iter().enumerate() {
                    let resp = rx.recv().expect(
                        "admitted request must resolve across the race");
                    match &resp.error {
                        None => {
                            let got: Vec<usize> = resp.items.iter()
                                .map(|&(i, _)| i).collect();
                            assert!(got == want_a[i] || got == want_b[i],
                                    "round {round} query {i} mixed \
                                     generations: {got:?}");
                        }
                        Some(ServeError::ShuttingDown) => {}
                        Some(other) => panic!(
                            "unexpected error during race: {other}"),
                    }
                }
            }
        });
        s.spawn(move || {
            std::thread::sleep(Duration::from_millis(5));
            // racing shutdown: accepted, refused, or tripped — but
            // never a hang, and never a half-installed generation
            let _ = server.swap_artifact(dir);
        });
        s.spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            server.shutdown();
        });
    });
    server.shutdown(); // idempotent after the raced shutdown
    let _ = std::fs::remove_dir_all(&dir);
}

/// Race leg: a rolling swap concurrent with fault-injected replica
/// restarts (the two paths take the same generation + session locks).
/// Must not deadlock; restarts and the swap both land; the replica
/// serves the swapped weights afterward.
#[test]
fn swap_racing_replica_restart_converges() {
    use bloomrec::artifact;

    let Some(f) = recurrent_fixture() else { return };
    let dir = std::env::temp_dir().join(format!(
        "bloomrec_swap_restart_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    // same weights: the race is about locks and liveness, not rankings
    let bloom = f.emb.as_bloom().expect("serving embedding is Bloom");
    artifact::pack(&dir, &f.predict, &f.state, Some(bloom))
        .expect("pack");

    let plan = FaultPlan::parse("fatal:1,fatal_budget:3")
        .expect("fault grammar");
    let server = Server::start(
        Arc::clone(&f.rt), f.predict.clone(), f.state.clone(),
        Arc::clone(&f.emb), ServeConfig {
            replicas: 1,
            faults: Some(Arc::new(plan)),
            batcher: BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(1),
            },
            ..ServeConfig::default()
        }).expect("server");

    // swap while the budgeted fatals are restarting the replica
    let report = server.swap_artifact(&dir).expect("swap accepted");
    assert!(!report.tripped);
    let restarts = wait_for(&server, 3, |s| s.replica_restarts);
    assert_eq!(restarts, 3, "all budgeted fatals restart, swap or not");

    // converged: the replica serves and caches sessions normally
    let click: u32 = f.ds.test.iter()
        .flat_map(|e| e.input_items().iter().copied())
        .find(|&i| i != PAD)
        .expect("a click");
    let resp = server.recommend(RecRequest::session(3, vec![click], 5));
    assert!(resp.error.is_none(), "{:?}", resp.error);
    assert_eq!(resp.items.len(), 5);
    assert_eq!(server.session_count(), 1);
    assert_eq!(server.metrics.snapshot().swaps_applied, 1);
    server.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// CI chaos leg (`--ignored chaos_smoke`, release profile, run under
/// `BLOOMREC_FAULT`): the Zipf harness drives a 2-replica tier with
/// injected panics and delays plus a default deadline, then forces
/// deterministic restarts and a retried swap. Asserts the tier's whole
/// fault contract: every admitted request resolves into exactly one
/// bucket (`completed + timed_out + failed == sent`), restarts are
/// observed and exact, swap retries land, and the tier still serves
/// bit-correct traffic afterward.
#[test]
#[ignore]
fn chaos_smoke() {
    use bloomrec::artifact;
    use bloomrec::serve::{run_load, LoadConfig};
    use bloomrec::util::rng::Rng;

    let Some(f) = recurrent_fixture() else { return };
    // the CI leg arms the plan via BLOOMREC_FAULT; running the test
    // directly falls back to an equivalent built-in chaos spec
    let spec = std::env::var("BLOOMREC_FAULT").unwrap_or_else(
        |_| "panic:0.05,delay:2ms:0.1,seed:7".to_string());
    let plan = Arc::new(FaultPlan::parse(&spec).expect("fault grammar"));

    let server = Server::start(
        Arc::clone(&f.rt), f.predict.clone(), f.state.clone(),
        Arc::clone(&f.emb), ServeConfig {
            replicas: 2,
            default_deadline: Some(Duration::from_millis(50)),
            batcher: BatcherConfig {
                max_batch: 16,
                max_wait: Duration::from_micros(200),
            },
            ..ServeConfig::default()
        }).expect("server");
    let mut rng = Rng::new(11);
    let pool = bloomrec::data::sequences::generate_serve_sessions(
        f.ds.d, 256, 6, &mut rng);
    let r = run_load(&server, &pool, &LoadConfig {
        users: 10_000,
        concurrency: 8,
        duration: Duration::from_millis(800),
        stateful: true,
        faults: Some(Arc::clone(&plan)),
        ..LoadConfig::default()
    });

    // the zero-drop ledger: every request in exactly one bucket
    assert!(r.sent > 0, "harness generated no traffic");
    assert_eq!(r.completed + r.timed_out + r.failed, r.sent,
               "requests leaked from the response ledger: {r:?}");
    assert!(r.completed > 0, "chaos drowned every request: {r:?}");
    // injected delays are 2 ms against a 50 ms deadline; p99 over the
    // whole run stays inside a loose budget even with panics
    assert!(r.p99_ms < 2_000.0, "p99 blew the chaos budget: {r:?}");

    // deterministic restart leg: two budgeted fatals, exactly counted
    let restarts0 = server.metrics.snapshot().replica_restarts;
    server.install_faults(Some(Arc::new(
        FaultPlan::parse("fatal:1,fatal_budget:2").expect("grammar"))));
    // wake both replicas so their flush loops reach the fatal site
    let click: u32 = f.ds.test.iter()
        .flat_map(|e| e.input_items().iter().copied())
        .find(|&i| i != PAD)
        .expect("a click");
    for sid in 0..4u64 {
        let _ = server.recommend(RecRequest::session(
            1000 + sid, vec![click], 5));
    }
    let restarts = wait_for(&server, restarts0 + 2,
                            |s| s.replica_restarts);
    assert_eq!(restarts, restarts0 + 2,
               "budgeted fatals must restart exactly twice");

    // swap-retry leg: one injected transient failure, absorbed
    let dir = std::env::temp_dir().join(format!(
        "bloomrec_chaos_swap_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let bloom = f.emb.as_bloom().expect("serving embedding is Bloom");
    artifact::pack(&dir, &f.predict, &f.state, Some(bloom))
        .expect("pack");
    server.install_faults(Some(Arc::new(
        FaultPlan::default().with_swap_fails(1))));
    let report = server.swap_artifact(&dir).expect("retry absorbs it");
    assert!(!report.tripped);
    let snap = server.metrics.snapshot();
    assert!(snap.swap_retries >= 1, "the transient failure retried");
    assert_eq!(snap.swaps_applied, 1);

    // all faults cleared: the tier serves clean traffic again
    server.install_faults(None);
    let resp = server.recommend(RecRequest::session(7, vec![click], 5));
    assert!(resp.error.is_none(), "{:?}", resp.error);
    assert_eq!(resp.items.len(), 5);
    server.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}
