//! Kernel-layer and batched-serving correctness:
//!
//! * property tests that the blocked `gemm` (plain and packed-B) agrees
//!   with a naive triple-loop matmul within 1e-5 across random shapes;
//! * property tests that the parallel kernel entry points (`par_gemm`,
//!   `PackedB::matmul`, `par_gemm_nt`, `par_gemm_tn_acc`,
//!   `par_spmm_gather`/`par_spmm_scatter`) are **bit-identical** to
//!   their serial arms across random shapes and thread counts — the
//!   determinism contract of the data-parallel execution layer;
//! * property tests that `Execution::step_batch` over N packed sessions
//!   is bit-identical to N sequential `Execution::step` calls —
//!   including sessions that ragged-join and leave mid-stream, the
//!   micro-batching server's actual access pattern;
//! * property tests that every SIMD level (`BLOOMREC_SIMD`) is
//!   **bit-identical** to the forced-scalar arm across all kernel entry
//!   points at ragged shapes, plus end-to-end train/predict parity and
//!   a dispatch-override assertion — the determinism contract of the
//!   SIMD microkernel tier.

use bloomrec::bloom::{decode_scores, HashMatrix};
use bloomrec::linalg::gemm::{gemm, gemm_nt, gemm_nt_relu_masked,
                             gemm_packed, gemm_tn_acc, matmul_into,
                             par_gemm, par_gemm_nt, par_gemm_tn_acc,
                             par_spmm_gather, par_spmm_scatter,
                             spmm_gather, spmm_scatter, PackedB};
use bloomrec::linalg::simd::{self, SimdLevel};
use bloomrec::model::ModelState;
use bloomrec::runtime::{test_ff_spec, test_rnn_spec, BatchInput,
                        BatchTarget, BatchedHiddenState, Execution,
                        HiddenState, HostTensor, NativeExecution,
                        RecurrentExecution, SparseBatch};
use bloomrec::util::proptest::check;
use bloomrec::util::rng::Rng;
use bloomrec::util::threadpool::WorkerPool;

/// Tests that mutate the process-global worker-pool size serialize on
/// this lock, so a concurrently running test cannot resize the pool
/// while a serial reference arm is mid-run (pool *readers* are safe —
/// results are thread-count-invariant — but the reference arms must
/// genuinely run serial to give the comparisons teeth).
static POOL_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

/// Same idea for the process-global SIMD dispatch level: results are
/// level-invariant by contract, but the parity tests' reference arms
/// must genuinely run scalar.
static SIMD_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

/// Scalar plus every SIMD level this host can actually execute
/// (`set_level` clamps unsupported requests to scalar, so probing via
/// the round trip is exact).
fn supported_simd_levels() -> Vec<SimdLevel> {
    let mut out = vec![SimdLevel::Scalar];
    for l in [SimdLevel::Sse, SimdLevel::Avx2, SimdLevel::Neon] {
        simd::set_level(Some(l));
        if simd::level() == l {
            out.push(l);
        }
    }
    simd::set_level(None);
    out
}

/// Naive i-j-k reference matmul (no blocking, no zero-skip, plain
/// per-element dot) — deliberately a DIFFERENT summation order than the
/// blocked kernel, so agreement is numeric (1e-5), not structural.
fn naive_matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize)
    -> Vec<f32> {
    let mut c = vec![0.0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0f32;
            for kk in 0..k {
                acc += a[i * k + kk] * b[kk * n + j];
            }
            c[i * n + j] = acc;
        }
    }
    c
}

fn rand_vec(rng: &mut Rng, len: usize, sparsity: f64) -> Vec<f32> {
    (0..len)
        .map(|_| {
            if rng.bool(sparsity) {
                0.0
            } else {
                rng.normal() as f32
            }
        })
        .collect()
}

/// Blocked gemm (plain, packed, and transpose-aware) vs the naive
/// matmul: within 1e-5 relative error on random shapes spanning the
/// tile boundaries.
#[test]
fn prop_blocked_gemm_matches_naive_matmul() {
    check("gemm-vs-naive", 0xCE11, 40,
          |rng| {
              let m = 1 + rng.below(12);
              let k = 1 + rng.below(300);
              let n = 1 + rng.below(200);
              let seed = rng.next_u64();
              (vec![m, k, n], seed)
          },
          |input| {
              let (dims, seed) = input;
              if dims.len() != 3 {
                  return Ok(()); // shrunk out of shape
              }
              let (m, k, n) = (dims[0], dims[1], dims[2]);
              if m == 0 || k == 0 || n == 0 {
                  return Ok(()); // shrunk outside the invariants
              }
              let mut rng = Rng::new(*seed);
              let a = rand_vec(&mut rng, m * k, 0.3);
              let b = rand_vec(&mut rng, k * n, 0.0);
              let want = naive_matmul(&a, &b, m, k, n);
              let tol = |w: f32| 1e-5f32 * w.abs().max(1.0);

              let mut c = vec![0.0f32; m * n];
              matmul_into(&a, &b, &mut c, m, k, n);
              for (i, (&got, &w)) in c.iter().zip(&want).enumerate() {
                  if (got - w).abs() > tol(w) {
                      return Err(format!(
                          "gemm {m}x{k}x{n} elem {i}: {got} vs {w}"));
                  }
              }

              // packed-B must be bit-identical to the plain kernel
              let bp = PackedB::pack(&b, k, n);
              let mut cp = vec![0.0f32; m * n];
              gemm_packed(&a, &bp, &mut cp, m, k, n, 0.0);
              if cp != c {
                  return Err(format!(
                      "packed gemm diverged from plain at {m}x{k}x{n}"));
              }

              // beta = 1 accumulates exactly once more
              gemm(&a, &b, &mut c, m, k, n, 1.0);
              for (i, (&got, &w)) in c.iter().zip(&want).enumerate() {
                  if (got - 2.0 * w).abs() > 2.0 * tol(w) {
                      return Err(format!(
                          "gemm beta=1 elem {i}: {got} vs {}", 2.0 * w));
                  }
              }

              // transpose-aware: A @ (B^T)^T == A @ B
              let mut bt = vec![0.0f32; n * k];
              for j in 0..n {
                  for kk in 0..k {
                      bt[j * k + kk] = b[kk * n + j];
                  }
              }
              let mut cnt = vec![0.0f32; m * n];
              gemm_nt(&a, &bt, &mut cnt, m, k, n, 0.0);
              for (i, (&got, &w)) in cnt.iter().zip(&want).enumerate() {
                  if (got - w).abs() > tol(w) {
                      return Err(format!(
                          "gemm_nt elem {i}: {got} vs {w}"));
                  }
              }
              Ok(())
          });
}

/// Every parallel kernel entry point must produce bit-identical output
/// to its serial arm for random shapes and thread counts — the
/// determinism contract the sharded trainer and the batched server are
/// built on. Small shapes fall back to the serial kernel (trivially
/// identical); shapes above the fan-out threshold genuinely split
/// across workers.
#[test]
fn prop_parallel_kernels_bit_identical_to_serial() {
    let _pool = POOL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    check("par-kernels-vs-serial", 0xBA12, 10,
          |rng| {
              let m = 1 + rng.below(96);
              let k = 1 + rng.below(160);
              let n = 1 + rng.below(160);
              let seed = rng.next_u64();
              (vec![m, k, n], seed)
          },
          |input| {
              let (dims, seed) = input;
              if dims.len() != 3 {
                  return Ok(()); // shrunk out of shape
              }
              let (m, k, n) = (dims[0], dims[1], dims[2]);
              if m == 0 || k == 0 || n == 0 {
                  return Ok(()); // shrunk outside the invariants
              }
              let mut rng = Rng::new(*seed);
              let a = rand_vec(&mut rng, m * k, 0.3);
              let b = rand_vec(&mut rng, k * n, 0.0);
              let bt = rand_vec(&mut rng, n * k, 0.0);
              let g = rand_vec(&mut rng, m * n, 0.0);
              // CSR rows over k positions (the sparse-batch mirror)
              let mut indptr = vec![0usize];
              let mut indices = Vec::new();
              let mut vals = Vec::new();
              for _ in 0..m {
                  let nnz = rng.below(k.min(40) + 1);
                  let mut pos: Vec<usize> = rng.sample_distinct(k, nnz);
                  pos.sort_unstable();
                  for i in pos {
                      indices.push(i as u32);
                      vals.push(rng.normal() as f32);
                  }
                  indptr.push(indices.len());
              }
              // serial references
              let mut c_ref = vec![0.0f32; m * n];
              gemm(&a, &b, &mut c_ref, m, k, n, 0.0);
              let bp = PackedB::pack(&b, k, n);
              let mut nt_ref = vec![0.0f32; m * n];
              gemm_nt(&a, &bt, &mut nt_ref, m, k, n, 0.0);
              let mut tn_ref = vec![0.0f32; k * n];
              gemm_tn_acc(&a, &g, &mut tn_ref, m, k, n);
              let mut gather_ref = vec![0.0f32; m * n];
              spmm_gather(&indptr, &indices, &vals, m, 0, 1, &b, n,
                          &mut gather_ref);
              let mut scatter_ref = vec![0.0f32; k * n];
              spmm_scatter(&indptr, &indices, &vals, m, 0, 1, &g, n,
                           &mut scatter_ref);

              for &threads in &[1usize, 2, 3, 6] {
                  WorkerPool::set_global_threads(threads);
                  let shape = format!("{m}x{k}x{n} t={threads}");
                  let mut c = vec![0.0f32; m * n];
                  par_gemm(&a, &b, &mut c, m, k, n, 0.0);
                  if c != c_ref {
                      return Err(format!("par_gemm diverged at {shape}"));
                  }
                  c.fill(0.0);
                  bp.matmul(&a, &mut c, m, 0.0);
                  if c != c_ref {
                      return Err(format!(
                          "PackedB::matmul diverged at {shape}"));
                  }
                  c.fill(0.0);
                  par_gemm_nt(&a, &bt, &mut c, m, k, n, 0.0);
                  if c != nt_ref {
                      return Err(format!(
                          "par_gemm_nt diverged at {shape}"));
                  }
                  let mut dw = vec![0.0f32; k * n];
                  par_gemm_tn_acc(&a, &g, &mut dw, m, k, n);
                  if dw != tn_ref {
                      return Err(format!(
                          "par_gemm_tn_acc diverged at {shape}"));
                  }
                  let mut out = vec![0.0f32; m * n];
                  par_spmm_gather(&indptr, &indices, &vals, m, 0, 1, &b,
                                  n, &mut out);
                  if out != gather_ref {
                      return Err(format!(
                          "par_spmm_gather diverged at {shape}"));
                  }
                  let mut dw = vec![0.0f32; k * n];
                  par_spmm_scatter(&indptr, &indices, &vals, m, 0, 1,
                                   &g, n, &mut dw);
                  if dw != scatter_ref {
                      return Err(format!(
                          "par_spmm_scatter diverged at {shape}"));
                  }
              }
              WorkerPool::set_global_threads(0);
              Ok(())
          });
}

/// Drive N sessions with ragged per-session click streams two ways —
/// sequentially (one `step` per session per click) and micro-batched
/// (gather the sessions active in each round, one `step_batch`, scatter
/// back, exactly like `serve::Server`) — and require bit-identical
/// hidden states and readouts. Sessions join late (empty early rounds)
/// and leave early (short streams), so every gather is a different
/// ragged subset.
#[test]
fn prop_step_batch_matches_sequential_ragged_sessions() {
    check("step-batch-ragged", 0x5E55, 14,
          |rng| {
              let m = 6 + rng.below(20);
              let h = 2 + rng.below(8);
              let n = 1 + rng.below(6);
              let lstm = rng.below(2);
              let seed = rng.next_u64();
              (vec![m, h, n, lstm], seed)
          },
          |input| {
              let (dims, seed) = input;
              if dims.len() != 4 {
                  return Ok(()); // shrunk out of shape
              }
              let (m, h, n, lstm) = (dims[0], dims[1], dims[2], dims[3]);
              if m == 0 || h == 0 || n == 0 {
                  return Ok(()); // shrunk outside the invariants
              }
              let family = if lstm == 1 { "lstm" } else { "gru" };
              let mut rng = Rng::new(*seed);
              let spec = test_rnn_spec(family, m, h, m, n, 4);
              let exe = RecurrentExecution::new(spec.clone())
                  .map_err(|e| e.to_string())?;
              let state = ModelState::init(&spec, &mut rng);

              // ragged streams: session s becomes active at round
              // `join[s]` and has `len[s]` clicks from there on
              let rounds = 5usize;
              let mut streams: Vec<Vec<Vec<(u32, f32)>>> = Vec::new();
              for _ in 0..n {
                  let join = rng.below(rounds);
                  let len = 1 + rng.below(rounds - join);
                  let clicks: Vec<Vec<(u32, f32)>> = (0..len)
                      .map(|_| vec![(rng.below(m) as u32, 1.0f32)])
                      .collect();
                  let mut stream = vec![Vec::new(); join];
                  stream.extend(clicks);
                  streams.push(stream);
              }

              // sequential ground truth: per-session rows=1 stepping
              let mut singles: Vec<HiddenState> = (0..n)
                  .map(|_| exe.begin_state(1).expect("state"))
                  .collect();
              for round in 0..rounds {
                  for (s, stream) in streams.iter().enumerate() {
                      if let Some(click) = stream.get(round) {
                          if click.is_empty() {
                              continue; // not joined yet
                          }
                          let mut sb = SparseBatch::new(m);
                          sb.push_row(click);
                          exe.step(&state.params, &mut singles[s],
                                   &BatchInput::Sparse(sb))
                              .map_err(|e| e.to_string())?;
                      }
                  }
              }

              // micro-batched: gather the active subset per round
              let mut batched: Vec<HiddenState> = (0..n)
                  .map(|_| exe.begin_state(1).expect("state"))
                  .collect();
              for round in 0..rounds {
                  let active: Vec<usize> = (0..n)
                      .filter(|&s| {
                          streams[s].get(round)
                              .is_some_and(|c| !c.is_empty())
                      })
                      .collect();
                  if active.is_empty() {
                      continue;
                  }
                  let refs: Vec<&HiddenState> =
                      active.iter().map(|&s| &batched[s]).collect();
                  let mut packed = BatchedHiddenState::gather(&refs)
                      .map_err(|e| e.to_string())?;
                  let mut sb = SparseBatch::new(m);
                  for &s in &active {
                      sb.push_row(&streams[s][round]);
                  }
                  exe.step_batch(&state.params, &mut packed,
                                 &BatchInput::Sparse(sb))
                      .map_err(|e| e.to_string())?;
                  for (row, &s) in active.iter().enumerate() {
                      packed.copy_row_into(row, &mut batched[s], 0)
                          .map_err(|e| e.to_string())?;
                  }
              }

              // states and readouts must agree bit-for-bit
              for s in 0..n {
                  if singles[s].h.data != batched[s].h.data {
                      return Err(format!(
                          "{family} session {s}: hidden state diverged"));
                  }
                  let a = exe.readout(&state.params, &singles[s])
                      .map_err(|e| e.to_string())?;
                  let b = exe.readout(&state.params, &batched[s])
                      .map_err(|e| e.to_string())?;
                  if a != b {
                      return Err(format!(
                          "{family} session {s}: readout diverged"));
                  }
              }
              // ...and the batched readout over ALL sessions matches
              let refs: Vec<&HiddenState> = batched.iter().collect();
              let packed = BatchedHiddenState::gather(&refs)
                  .map_err(|e| e.to_string())?;
              let all = exe.readout_batch(&state.params, &packed)
                  .map_err(|e| e.to_string())?;
              for (s, single) in singles.iter().enumerate() {
                  let one = exe.readout(&state.params, single)
                      .map_err(|e| e.to_string())?;
                  if all.data[s * m..(s + 1) * m] != one.data[..] {
                      return Err(format!(
                          "{family} session {s}: batched readout \
                           diverged"));
                  }
              }
              Ok(())
          });
}

/// Every kernel entry point at every supported SIMD level must be
/// bit-identical to the forced-scalar arm — at ragged shapes (m, k, n
/// not multiples of the lane width), zero-skip rows, and
/// beta ∈ {0, 1, other}. This is the SIMD tier's determinism contract:
/// lanes own output elements only, so parity is structural.
#[test]
fn prop_simd_kernels_bit_identical_to_scalar() {
    let _simd = SIMD_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let levels = supported_simd_levels();
    check("simd-kernels-vs-scalar", 0x51D0, 12,
          |rng| {
              // deliberately odd-biased shapes: ragged lane tails
              let m = 1 + rng.below(21);
              let k = 1 + rng.below(131);
              let n = 1 + rng.below(131);
              let seed = rng.next_u64();
              (vec![m, k, n], seed)
          },
          |input| {
              let (dims, seed) = input;
              if dims.len() != 3 {
                  return Ok(()); // shrunk out of shape
              }
              let (m, k, n) = (dims[0], dims[1], dims[2]);
              if m == 0 || k == 0 || n == 0 {
                  return Ok(()); // shrunk outside the invariants
              }
              let mut rng = Rng::new(*seed);
              let a = rand_vec(&mut rng, m * k, 0.3);
              let b = rand_vec(&mut rng, k * n, 0.0);
              let bt = rand_vec(&mut rng, n * k, 0.2);
              let g = rand_vec(&mut rng, m * n, 0.0);
              let h = rand_vec(&mut rng, m * k, 0.5); // relu mask input
              let seed_c = rand_vec(&mut rng, m * n, 0.0);
              // CSR rows over k positions
              let mut indptr = vec![0usize];
              let mut indices = Vec::new();
              let mut vals = Vec::new();
              for _ in 0..m {
                  let nnz = rng.below(k.min(30) + 1);
                  let mut pos: Vec<usize> = rng.sample_distinct(k, nnz);
                  pos.sort_unstable();
                  for i in pos {
                      indices.push(i as u32);
                      vals.push(rng.normal() as f32);
                  }
                  indptr.push(indices.len());
              }
              // a decode sweep (d items over a k-probe hash matrix)
              let dd = 3 + rng.below(90);
              let mm = 8 + rng.below(24);
              let kk = 1 + rng.below(5);
              let hm = HashMatrix::random(dd, mm, kk, &mut rng);
              let probs: Vec<f32> =
                  (0..mm).map(|_| rng.f32() + 1e-3).collect();
              let bp = PackedB::pack(&b, k, n);

              let run_all = |lvl: SimdLevel| -> Vec<Vec<f32>> {
                  simd::set_level(Some(lvl));
                  let mut out: Vec<Vec<f32>> = Vec::new();
                  for &beta in &[0.0f32, 1.0, 0.37] {
                      let mut c = seed_c.clone();
                      gemm(&a, &b, &mut c, m, k, n, beta);
                      out.push(c);
                      let mut c = seed_c.clone();
                      gemm_packed(&a, &bp, &mut c, m, k, n, beta);
                      out.push(c);
                      let mut c = seed_c.clone();
                      gemm_nt(&a, &bt, &mut c, m, k, n, beta);
                      out.push(c);
                  }
                  let mut dw = vec![0.0f32; k * n];
                  gemm_tn_acc(&a, &g, &mut dw, m, k, n);
                  out.push(dw);
                  // g [m, n] @ b^T with b [k, n]: rows=m, p=n, out=k
                  let mut gp = vec![0.0f32; m * k];
                  gemm_nt_relu_masked(&g, &b, &h, &mut gp, m, n, k);
                  out.push(gp);
                  let mut o = seed_c.clone();
                  spmm_gather(&indptr, &indices, &vals, m, 0, 1, &b, n,
                              &mut o);
                  out.push(o);
                  let mut dw = vec![0.0f32; k * n];
                  spmm_scatter(&indptr, &indices, &vals, m, 0, 1, &g, n,
                               &mut dw);
                  out.push(dw);
                  out.push(decode_scores(&probs, &hm));
                  out
              };
              let want = run_all(SimdLevel::Scalar);
              for &lvl in &levels[1..] {
                  let got = run_all(lvl);
                  if got != want {
                      simd::set_level(None);
                      return Err(format!(
                          "{} diverged from scalar at {m}x{k}x{n}",
                          lvl.name()));
                  }
              }
              simd::set_level(None);
              Ok(())
          });
}

/// End-to-end SIMD parity: whole train steps (every optimizer, both
/// loss families, FF and recurrent) and predicts must produce
/// bit-identical losses, parameters, optimizer state and outputs under
/// forced-scalar and the detected SIMD level — the activation /
/// optimizer / loss sweeps all ride the dispatched tier.
#[test]
fn simd_train_and_predict_bit_identical_to_scalar() {
    let _simd = SIMD_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let levels = supported_simd_levels();

    // FF grid: optimizer x loss
    for &(optimizer, slots) in &[("adam", 2usize), ("sgd", 1),
                                 ("rmsprop", 1), ("adagrad", 1)] {
        for loss in ["softmax_ce", "cosine"] {
            let mut spec = test_ff_spec(19, &[13], 19, 3);
            spec.optimizer = optimizer.into();
            spec.opt_slots = slots;
            spec.loss = loss.into();
            let mut rng = Rng::new(0xF00D);
            let state0 = ModelState::init(&spec, &mut rng);
            let mut x = HostTensor::zeros(&[3, 19]);
            let mut y = HostTensor::zeros(&[3, 19]);
            for v in x.data.iter_mut() {
                if rng.bool(0.3) {
                    *v = 1.0;
                }
            }
            for v in y.data.iter_mut() {
                if rng.bool(0.3) {
                    *v = 1.0;
                }
            }
            let exe = NativeExecution::new(spec.clone()).unwrap();
            let run = |lvl: SimdLevel| {
                simd::set_level(Some(lvl));
                let mut st = state0.clone();
                let mut losses = Vec::new();
                for _ in 0..3 {
                    losses.push(
                        exe.train_step(&mut st,
                                       &BatchInput::Dense(x.clone()),
                                       &BatchTarget::Dense(y.clone()))
                            .unwrap());
                }
                let out = exe
                    .predict(&st.params, &BatchInput::Dense(x.clone()))
                    .unwrap();
                (losses, st, out)
            };
            let (l_s, st_s, out_s) = run(SimdLevel::Scalar);
            for &lvl in &levels[1..] {
                let (l_v, st_v, out_v) = run(lvl);
                assert_eq!(l_s, l_v,
                           "{optimizer}/{loss} loss diverged at {}",
                           lvl.name());
                assert_eq!(st_s.params, st_v.params,
                           "{optimizer}/{loss} params diverged at {}",
                           lvl.name());
                assert_eq!(st_s.opt_state, st_v.opt_state,
                           "{optimizer}/{loss} opt state diverged at {}",
                           lvl.name());
                assert_eq!(out_s, out_v,
                           "{optimizer}/{loss} predict diverged at {}",
                           lvl.name());
            }
            simd::set_level(None);
        }
    }

    // recurrent: one GRU and one LSTM trajectory
    for family in ["gru", "lstm"] {
        let spec = test_rnn_spec(family, 11, 6, 11, 2, 3);
        let mut rng = Rng::new(0xBEEF);
        let state0 = ModelState::init(&spec, &mut rng);
        let mut x = HostTensor::zeros(&[2, 3, 11]);
        let mut y = HostTensor::zeros(&[2, 11]);
        for v in x.data.iter_mut() {
            if rng.bool(0.25) {
                *v = 1.0;
            }
        }
        for v in y.data.iter_mut() {
            if rng.bool(0.25) {
                *v = 1.0;
            }
        }
        let exe = RecurrentExecution::new(spec.clone()).unwrap();
        let run = |lvl: SimdLevel| {
            simd::set_level(Some(lvl));
            let mut st = state0.clone();
            let mut losses = Vec::new();
            for _ in 0..2 {
                losses.push(
                    exe.train_step(&mut st,
                                   &BatchInput::Dense(x.clone()),
                                   &BatchTarget::Dense(y.clone()))
                        .unwrap());
            }
            let out = exe
                .predict(&st.params, &BatchInput::Dense(x.clone()))
                .unwrap();
            (losses, st, out)
        };
        let (l_s, st_s, out_s) = run(SimdLevel::Scalar);
        for &lvl in &levels[1..] {
            let (l_v, st_v, out_v) = run(lvl);
            assert_eq!(l_s, l_v, "{family} loss diverged at {}",
                       lvl.name());
            assert_eq!(st_s.params, st_v.params,
                       "{family} params diverged at {}", lvl.name());
            assert_eq!(out_s, out_v, "{family} predict diverged at {}",
                       lvl.name());
        }
        simd::set_level(None);
    }
}

/// The dispatcher must honor `BLOOMREC_SIMD`: under a forced `=0` run
/// (the CI scalar leg) the active level is Scalar; under any other
/// parseable value it is that level clamped to host support; with no
/// override it equals detection.
#[test]
fn simd_dispatch_honors_env_override() {
    let _simd = SIMD_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    simd::set_level(None); // drop any runtime override, re-read the env
    let active = simd::level();
    match std::env::var("BLOOMREC_SIMD")
        .ok()
        .as_deref()
        .and_then(SimdLevel::parse)
    {
        Some(SimdLevel::Scalar) => {
            assert_eq!(active, SimdLevel::Scalar,
                       "BLOOMREC_SIMD=0 must force the scalar arms");
        }
        Some(want) => {
            assert!(active == want || active == SimdLevel::Scalar,
                    "override {} must dispatch to it or clamp to \
                     scalar, got {}", want.name(), active.name());
        }
        None => {
            assert_eq!(active, simd::detected_level(),
                       "no override: dispatch follows detection");
        }
    }
}
