//! Artifact round-trip property suite: train → pack → load must be
//! bit-identical — weights, hash tables, predictions, and top-N
//! decodes — for every model family (ff/gru/lstm), both losses, and
//! random wire shapes. Corrupt artifacts (flipped bytes, truncation,
//! schema bumps, shape lies) must be rejected with a useful error
//! before a single weight is used.

use std::fs;
use std::path::{Path, PathBuf};

use bloomrec::artifact::{self, MANIFEST_FILE, PAYLOAD_FILE};
use bloomrec::bloom::{DecodeScratch, HashMatrix};
use bloomrec::embedding::{Bloom, Embedding};
use bloomrec::linalg::Precision;
use bloomrec::model::ModelState;
use bloomrec::runtime::{test_ff_spec, test_rnn_spec, ArtifactSpec,
                        BatchInput, BatchTarget, HostTensor, Runtime};
use bloomrec::util::json::Json;
use bloomrec::util::rng::Rng;

fn tmp(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "bloomrec_artifact_test_{tag}_{}", std::process::id()));
    let _ = fs::remove_dir_all(&dir);
    dir
}

fn runtime() -> Runtime {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    Runtime::native(&dir).expect("native runtime")
}

fn random_tensor(shape: &[usize], rng: &mut Rng) -> HostTensor {
    let n: usize = shape.iter().product();
    HostTensor::from_vec(shape, (0..n).map(|_| rng.f32()).collect())
}

/// Random multi-hot-ish target with at least one hot position per row
/// (keeps the cosine loss away from zero-norm rows).
fn random_target(shape: &[usize], rng: &mut Rng) -> HostTensor {
    let (rows, cols) = (shape[0], shape[1]);
    let mut t = HostTensor::zeros(shape);
    for r in 0..rows {
        for c in 0..cols {
            if rng.bool(0.2) {
                t.data[r * cols + c] = 1.0;
            }
        }
        t.data[r * cols + rng.below(cols)] = 1.0;
    }
    t
}

/// Train a small model of the given family/loss on random data with
/// randomized wire shapes, and return the predict-kind spec, the
/// trained weights, and a Bloom config matching the wire.
fn trained_case(rt: &Runtime, family: &str, loss: &str, seed: u64)
    -> (ArtifactSpec, ModelState, Bloom) {
    let mut rng = Rng::new(seed.wrapping_mul(0x9E37) ^ 0xA57);
    let m_in = 12 + rng.below(24);
    let m_out = 12 + rng.below(24);
    let batch = 2 + rng.below(3);
    let mut train = if family == "ff" {
        let hidden = [6 + rng.below(10)];
        test_ff_spec(m_in, &hidden, m_out, batch)
    } else {
        let hidden = 8 + rng.below(8);
        let seq_len = 2 + rng.below(3);
        test_rnn_spec(family, m_in, hidden, m_out, batch, seq_len)
    };
    train.name = format!("art_{family}_{loss}_{seed}");
    train.loss = loss.to_string();
    let mut predict = train.clone();
    predict.kind = "predict".to_string();
    predict.opt_slots = 0;
    predict.name = format!("{}_predict", train.name);

    let exe = rt.load_spec(&train).expect("train execution");
    let mut state = ModelState::init(&train, &mut rng);
    for _ in 0..3 {
        let x = random_tensor(&train.x_shape(), &mut rng);
        let y = random_target(&train.y_shape(), &mut rng);
        exe.train_step_sharded(&mut state, &BatchInput::Dense(x),
                               &BatchTarget::Dense(y), 0)
            .expect("train step");
    }

    // a catalog over both wires; separate in/out tables exercise the
    // dual-segment path
    let d = 4 * m_in.max(m_out);
    let hm_in = HashMatrix::random(d, m_in, 3, &mut rng);
    let hm_out = HashMatrix::random(d, m_out, 3, &mut rng);
    (predict, state, Bloom::new(hm_in, Some(hm_out)))
}

/// The tentpole property: for every family × loss × seed, a packed and
/// reloaded model is indistinguishable from the in-memory one — same
/// weight bits, same hash tables, same predict outputs, same top-N
/// decode — without rerunning training.
#[test]
fn round_trip_is_bit_identical_across_families_and_losses() {
    let rt = runtime();
    for family in ["ff", "gru", "lstm"] {
        for loss in ["softmax_ce", "cosine"] {
            for seed in [1u64, 2] {
                let tag = format!("rt_{family}_{loss}_{seed}");
                let dir = tmp(&tag);
                let (predict, state, bloom) =
                    trained_case(&rt, family, loss, seed);
                artifact::pack(&dir, &predict, &state, Some(&bloom))
                    .expect("pack");
                let loaded = artifact::load(&dir).expect("load");

                // 1. weights round-trip bitwise
                assert_eq!(loaded.state.params.len(), state.params.len());
                for (a, b) in loaded.state.params.iter()
                    .zip(&state.params) {
                    assert_eq!(a.shape, b.shape, "{tag}");
                    assert_eq!(a.data, b.data,
                               "{tag}: weights must be bit-identical");
                }

                // 2. hash tables round-trip exactly
                let hin = loaded.hash_in.as_ref().expect("input table");
                let hout = loaded.hash_out.as_ref().expect("output table");
                assert_eq!(hin.h, bloom.hm_in.h, "{tag}");
                let bout = bloom.hm_out.as_ref().unwrap();
                assert_eq!(hout.h, bout.h, "{tag}");
                assert_eq!((hout.d, hout.m, hout.k),
                           (bout.d, bout.m, bout.k), "{tag}");

                // 3. predictions are bit-identical through the packed
                //    spec (loaded.spec compiles its own execution)
                let exe_a = rt.load_spec(&predict).expect("exe a");
                let exe_b = rt.load_spec(&loaded.spec).expect("exe b");
                let mut rng = Rng::new(seed ^ 0xF00D);
                let x = random_tensor(&predict.x_shape(), &mut rng);
                let out_a = exe_a
                    .predict(&state.params, &BatchInput::Dense(x.clone()))
                    .expect("predict a");
                let out_b = exe_b
                    .predict(&loaded.state.params, &BatchInput::Dense(x))
                    .expect("predict b");
                assert_eq!(out_a.shape, out_b.shape, "{tag}");
                assert_eq!(out_a.data, out_b.data,
                           "{tag}: predictions must be bit-identical");

                // 4. top-N decode agrees item-for-item, score-for-score
                let emb_b = loaded.embedding().expect("embedding");
                let row = &out_a.data[..predict.m_out];
                let excl: &[u32] = &[0, 3];
                let (mut sc_a, mut sc_b) =
                    (DecodeScratch::new(), DecodeScratch::new());
                let (mut top_a, mut top_b) = (Vec::new(), Vec::new());
                bloom.decode_top_n_into(row, excl, 5, None, &mut sc_a,
                                        &mut top_a);
                emb_b.decode_top_n_into(row, excl, 5, None, &mut sc_b,
                                        &mut top_b);
                assert_eq!(top_a, top_b,
                           "{tag}: decode_top_n must be bit-identical");
                let _ = fs::remove_dir_all(&dir);
            }
        }
    }
}

/// Regression for the int8 schema bump: f32 artifacts keep writing
/// schema version 1 with no quant section, version-1 artifacts keep
/// loading, and the loaded model keeps serving bit-identically. An
/// existing artifact fleet must never need a re-pack just because the
/// reader learned a second schema.
#[test]
fn schema_v1_f32_artifacts_keep_loading_and_serving() {
    let rt = runtime();
    let dir = tmp("v1_compat");
    let (predict, state, bloom) = trained_case(&rt, "ff", "softmax_ce", 21);
    artifact::pack(&dir, &predict, &state, Some(&bloom)).expect("pack");
    let text = fs::read_to_string(dir.join(MANIFEST_FILE)).unwrap();
    assert!(text.contains("\"schema_version\": 1"),
            "f32 packs must stay schema v1");
    assert!(!text.contains("\"quant\""),
            "f32 manifests must not carry a quant section");
    let loaded = artifact::load(&dir).expect("v1 artifact loads");
    assert!(loaded.quant.is_none());
    let exe = rt.load_spec(&loaded.spec).expect("exe");
    let mut rng = Rng::new(0x51);
    let x = random_tensor(&predict.x_shape(), &mut rng);
    let a = exe.predict(&state.params, &BatchInput::Dense(x.clone()))
        .expect("predict in-memory");
    let b = exe.predict(&loaded.state.params, &BatchInput::Dense(x))
        .expect("predict loaded");
    assert_eq!(a.data, b.data, "v1 round trip must stay bit-identical");
    let _ = fs::remove_dir_all(&dir);
}

/// The int8 tier end to end at a realistic weight shape: pack shrinks
/// the weight payload >= 3.5x vs the f32 pack of the same model, the
/// artifact reloads with its panels intact, and the quantized predict
/// tracks the f32 oracle within a loose distribution tolerance.
#[test]
fn int8_artifact_shrinks_payload_and_serves_within_tolerance() {
    let rt = runtime();
    let mut rng = Rng::new(0xA11CE);
    let mut spec = test_ff_spec(256, &[128], 256, 4);
    spec.kind = "predict".to_string();
    spec.opt_slots = 0;
    spec.name = "art_int8_roundtrip".to_string();
    let state = ModelState::init(&spec, &mut rng);
    let bloom = Bloom::new(HashMatrix::random(1024, 256, 3, &mut rng),
                           None);

    let fdir = tmp("int8_f32_base");
    let f32_report = artifact::pack(&fdir, &spec, &state, Some(&bloom))
        .expect("f32 pack");

    let qdir = tmp("int8_quant");
    spec.precision = Precision::Int8;
    let q_report = artifact::pack(&qdir, &spec, &state, Some(&bloom))
        .expect("int8 pack");
    // the acceptance floor: weight payload shrinks >= 3.5x (panels are
    // 1 byte/weight + one f32 scale per 256x64 block; biases stay f32)
    assert!(q_report.weight_bytes * 7 <= f32_report.weight_bytes * 2,
            "int8 weights {} bytes vs f32 {} bytes — under 3.5x",
            q_report.weight_bytes, f32_report.weight_bytes);

    let loaded = artifact::load(&qdir).expect("int8 artifact loads");
    assert_eq!(loaded.spec.precision, Precision::Int8);
    let quant = loaded.quant.as_ref().expect("panels survive the trip");
    let exe = rt.load_spec(&loaded.spec).expect("exe");
    let x = random_tensor(&spec.x_shape(), &mut rng);
    let oracle = exe
        .predict(&state.params, &BatchInput::Dense(x.clone()))
        .expect("f32 oracle");
    let got = exe
        .predict_quantized(quant, &BatchInput::Dense(x))
        .expect("quantized predict");
    assert_eq!(oracle.shape, got.shape);
    for (row, chunk) in got.data.chunks(spec.m_out).enumerate() {
        let sum: f32 = chunk.iter().sum();
        assert!((sum - 1.0).abs() < 1e-4,
                "row {row} softmax sums to {sum}");
    }
    for (i, (a, b)) in oracle.data.iter().zip(&got.data).enumerate() {
        assert!((a - b).abs() < 0.05,
                "probability {i} drifted: f32 {a} vs int8 {b}");
    }
    let _ = fs::remove_dir_all(&fdir);
    let _ = fs::remove_dir_all(&qdir);
}

#[test]
fn flipped_payload_byte_is_rejected_before_use() {
    let rt = runtime();
    let dir = tmp("corrupt_flip");
    let (predict, state, bloom) = trained_case(&rt, "ff", "softmax_ce", 7);
    artifact::pack(&dir, &predict, &state, Some(&bloom)).expect("pack");
    let p = dir.join(PAYLOAD_FILE);
    let orig = fs::read(&p).unwrap();
    // a flip anywhere — first byte, a middle weight, the hash-table
    // tail — must fail the checksum gate
    for pos in [0, orig.len() / 2, orig.len() - 1] {
        let mut bytes = orig.clone();
        bytes[pos] ^= 0x80;
        fs::write(&p, &bytes).unwrap();
        let err = artifact::load(&dir).unwrap_err();
        assert!(err.to_string().contains("checksum"),
                "byte {pos}: {err}");
    }
    fs::write(&p, &orig).unwrap();
    assert!(artifact::load(&dir).is_ok(), "restored payload loads");
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn bumped_schema_version_is_rejected_with_version_error() {
    let rt = runtime();
    let dir = tmp("corrupt_schema");
    let (predict, state, bloom) = trained_case(&rt, "ff", "softmax_ce", 8);
    artifact::pack(&dir, &predict, &state, Some(&bloom)).expect("pack");
    let mpath = dir.join(MANIFEST_FILE);
    let text = fs::read_to_string(&mpath).unwrap();
    assert!(text.contains("\"schema_version\": 1"), "pretty format moved");
    fs::write(&mpath,
              text.replace("\"schema_version\": 1",
                           "\"schema_version\": 999"))
        .unwrap();
    let err = artifact::load(&dir).unwrap_err();
    assert!(err.to_string().contains("schema version"), "{err}");
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn truncated_payload_is_rejected_cleanly() {
    let rt = runtime();
    let dir = tmp("corrupt_trunc");
    let (predict, state, bloom) = trained_case(&rt, "gru", "softmax_ce", 9);
    artifact::pack(&dir, &predict, &state, Some(&bloom)).expect("pack");
    let p = dir.join(PAYLOAD_FILE);
    let orig = fs::read(&p).unwrap();
    for cut in [0, 1, orig.len() / 3, orig.len() - 1] {
        fs::write(&p, &orig[..cut]).unwrap();
        // must be a clean error — no panic, no partial load
        let err = artifact::load(&dir).unwrap_err();
        assert!(err.to_string().contains("truncated"),
                "cut {cut}: {err}");
    }
    // a payload that GREW is just as invalid
    let mut grown = orig.clone();
    grown.extend_from_slice(&[0u8; 16]);
    fs::write(&p, &grown).unwrap();
    assert!(artifact::load(&dir).is_err());
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn manifest_payload_shape_mismatch_is_an_error_not_ub() {
    let rt = runtime();
    let dir = tmp("corrupt_shape");
    let (predict, state, bloom) = trained_case(&rt, "ff", "cosine", 10);
    artifact::pack(&dir, &predict, &state, Some(&bloom)).expect("pack");
    let mpath = dir.join(MANIFEST_FILE);
    let pristine = fs::read_to_string(&mpath).unwrap();

    // (a) a tensor segment whose shape disagrees with the spec
    let mut root = Json::parse(&pristine).unwrap();
    let lie = Json::Arr(vec![Json::from(1usize), Json::from(1usize)]);
    if let Json::Obj(m) = &mut root {
        let Some(Json::Arr(tensors)) = m.get_mut("tensors") else {
            panic!("manifest lost its tensors")
        };
        let Json::Obj(seg) = &mut tensors[0] else {
            panic!("segment is not an object")
        };
        seg.insert("shape".to_string(), lie.clone());
    }
    fs::write(&mpath, root.to_string_pretty()).unwrap();
    let err = artifact::load(&dir).unwrap_err();
    assert!(err.to_string().contains("does not match spec"), "{err}");

    // (b) spec AND segment lie consistently — caught against the
    //     payload byte count instead (shape mismatch, never a bad read)
    let mut root = Json::parse(&pristine).unwrap();
    if let Json::Obj(m) = &mut root {
        if let Some(Json::Arr(tensors)) = m.get_mut("tensors") {
            if let Json::Obj(seg) = &mut tensors[0] {
                seg.insert("shape".to_string(), lie.clone());
            }
        }
        if let Some(Json::Obj(spec)) = m.get_mut("spec") {
            if let Some(Json::Arr(params)) = spec.get_mut("params") {
                if let Json::Obj(p0) = &mut params[0] {
                    p0.insert("shape".to_string(), lie);
                }
            }
        }
    }
    fs::write(&mpath, root.to_string_pretty()).unwrap();
    let err = artifact::load(&dir).unwrap_err();
    assert!(err.to_string().contains("shape mismatch"), "{err}");

    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn missing_or_foreign_files_are_rejected() {
    let rt = runtime();
    let dir = tmp("corrupt_missing");
    let (predict, state, bloom) = trained_case(&rt, "ff", "softmax_ce", 11);
    artifact::pack(&dir, &predict, &state, Some(&bloom)).expect("pack");

    // payload gone
    fs::remove_file(dir.join(PAYLOAD_FILE)).unwrap();
    assert!(artifact::load(&dir).is_err(), "missing payload must fail");

    // a stray JSON file is not an artifact manifest
    fs::write(dir.join(MANIFEST_FILE), "{\"batch\": 64}").unwrap();
    let err = artifact::load(&dir).unwrap_err();
    assert!(err.to_string().contains("not a bloomrec artifact"), "{err}");

    // no directory at all
    assert!(artifact::load(Path::new("/nonexistent/bloomrec")).is_err());
    let _ = fs::remove_dir_all(&dir);
}

/// Packing validates against the spec BEFORE writing: a weight set
/// from a different architecture never produces an artifact.
#[test]
fn pack_rejects_mismatched_state() {
    let rt = runtime();
    let dir = tmp("pack_reject");
    let (predict, state, bloom) = trained_case(&rt, "ff", "softmax_ce", 12);

    let mut wrong_shape = state.clone();
    wrong_shape.params[0] = HostTensor::zeros(&[1, 1]);
    let err = artifact::pack(&dir, &predict, &wrong_shape, Some(&bloom))
        .unwrap_err();
    assert!(err.to_string().contains("shape"), "{err}");

    let mut fewer = state.clone();
    fewer.params.pop();
    let err = artifact::pack(&dir, &predict, &fewer, Some(&bloom))
        .unwrap_err();
    assert!(err.to_string().contains("tensors"), "{err}");

    assert!(!dir.join(MANIFEST_FILE).exists(),
            "rejected pack must not leave files behind");
    let _ = fs::remove_dir_all(&dir);
}
