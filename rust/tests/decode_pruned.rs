//! Integration tests for the candidate-pruned decode tier: the
//! inverted position index must round-trip the hash matrix (serially
//! and in parallel, bit-identically), and the pruned scorer must hold
//! its contract against the exhaustive oracle — bitwise-equal scores,
//! recall above [`RECALL_BOUND`] on structured requests, *exactly*
//! 1.0 whenever the knobs cover the catalog (the guaranteed-exact
//! fallback), and full correctness through dirty reused scratch.
//!
//! The `#[ignore]` smoke at the bottom is the catalog-scale leg CI
//! runs in release mode: a million-item Zipf catalog decoded through
//! the pruned tier.

use bloomrec::bloom::{decode_exhaustive_top_n_into,
                      decode_pruned_top_n_into, decode_scores,
                      DecodeScratch, DecodeStrategy, HashMatrix,
                      PositionIndex};
use bloomrec::data::zipf::ZipfStream;
use bloomrec::embedding::{Bloom, Embedding};
use bloomrec::util::rng::Rng;
use bloomrec::util::threadpool::WorkerPool;

/// Minimum mean recall@10 of the pruned tier vs the exhaustive oracle
/// on structured requests (boosted items > top-N, so the true top-N
/// always lives inside the candidate set — the observed recall is
/// 1.0; the bound leaves slack only for degenerate rng collisions).
const RECALL_BOUND: f64 = 0.99;

/// Output probabilities a trained head would emit: low noise
/// everywhere, `boost` distinct items' positions pushed far above the
/// noise floor. Boosted logs are >= ln(0.5) while noise logs are
/// <= ln(0.0101), so fully-boosted items strictly dominate the
/// ranking and their positions strictly dominate the top-P selection.
fn structured_probs(hm: &HashMatrix, boost: usize, rng: &mut Rng)
    -> Vec<f32> {
    let mut probs: Vec<f32> =
        (0..hm.m).map(|_| rng.f32() * 0.01 + 1e-4).collect();
    let mut boosted: Vec<usize> = Vec::with_capacity(boost);
    while boosted.len() < boost {
        let item = rng.below(hm.d);
        if boosted.contains(&item) {
            continue;
        }
        boosted.push(item);
        for &p in hm.row(item) {
            probs[p as usize] = 0.5 + rng.f32() * 0.5;
        }
    }
    probs
}

fn recall(want: &[(usize, f32)], got: &[(usize, f32)]) -> f64 {
    let hits = want.iter()
        .filter(|(i, _)| got.iter().any(|(j, _)| j == i))
        .count();
    hits as f64 / want.len().max(1) as f64
}

#[test]
fn index_round_trips_the_hash_matrix() {
    let hm = HashMatrix::random(10_000, 512, 4, &mut Rng::new(5));
    let idx = PositionIndex::build(&hm);
    let mut total = 0usize;
    for p in 0..hm.m {
        let post = idx.posting(p);
        total += post.len();
        assert!(post.windows(2).all(|w| w[0] < w[1]),
                "posting {p} must strictly ascend");
    }
    assert_eq!(total, hm.d * hm.k, "every probe indexed exactly once");
    for item in 0..hm.d {
        for &p in hm.row(item) {
            assert!(idx.posting(p as usize)
                        .binary_search(&(item as u32))
                        .is_ok(),
                    "item {item} missing from posting {p}");
        }
    }
}

#[test]
fn parallel_index_build_is_bit_identical_to_serial() {
    // clears the d*k >= 2^16 fan-out threshold, including thread
    // counts that do not divide d evenly
    let hm = HashMatrix::random(30_000, 1024, 4, &mut Rng::new(9));
    let serial = PositionIndex::build(&hm);
    for threads in [2usize, 5, 16] {
        let par = PositionIndex::build_with(
            &hm, WorkerPool::with_threads(threads));
        for p in 0..hm.m {
            assert_eq!(par.posting(p), serial.posting(p),
                       "posting {p} differs at t={threads}");
        }
    }
}

#[test]
fn exact_fallback_when_candidates_cover_catalog() {
    let hm = HashMatrix::random(800, 96, 3, &mut Rng::new(13));
    let idx = PositionIndex::build(&hm);
    let mut rng = Rng::new(14);
    let probs = structured_probs(&hm, 16, &mut rng);
    let mut scratch = DecodeScratch::new();
    let (mut want, mut got) = (Vec::new(), Vec::new());
    decode_exhaustive_top_n_into(&hm, &probs, &[3, 7], 10,
                                 &mut scratch, &mut want);
    let st = decode_pruned_top_n_into(&hm, &idx, 8, hm.d, &probs,
                                      &[3, 7], 10, &mut scratch,
                                      &mut got);
    assert!(st.pruned && st.fallback, "cap >= d must fall back");
    assert_eq!(st.scored, hm.d);
    assert_eq!(got, want, "fallback must equal the oracle exactly");
    assert_eq!(recall(&want, &got), 1.0,
               "recall is exactly 1.0 when max_candidates >= d");

    // the same contract through the Embedding strategy route
    let be = Bloom::new(hm.clone(), None)
        .with_decode(DecodeStrategy::Pruned {
            top_positions: 8,
            max_candidates: hm.d,
        });
    let mut via_emb = Vec::new();
    let st = be.decode_top_n_into(&probs, &[3, 7], 10, None,
                                  &mut scratch, &mut via_emb);
    assert!(st.pruned && st.fallback);
    assert_eq!(via_emb, want);
}

#[test]
fn pruned_recall_meets_bound_across_shapes() {
    let mut pruned_for_real = 0usize;
    for (case, &(d, m, k)) in
        [(500usize, 64usize, 3usize), (2000, 256, 4), (5000, 512, 2)]
            .iter()
            .enumerate()
    {
        let mut rng = Rng::new(100 + case as u64);
        let hm = HashMatrix::random(d, m, k, &mut rng);
        let idx = PositionIndex::build(&hm);
        // top-P covers every boosted position (12*k of them), the cap
        // tolerates the merged posting lists without covering d
        let (top_positions, max_candidates) = (12 * k + 8, d - 1);
        let mut scratch = DecodeScratch::new();
        let (mut want, mut got) = (Vec::new(), Vec::new());
        let mut total_recall = 0.0f64;
        let n_requests = 20usize;
        for _ in 0..n_requests {
            let probs = structured_probs(&hm, 12, &mut rng);
            decode_exhaustive_top_n_into(&hm, &probs, &[], 10,
                                         &mut scratch, &mut want);
            let st = decode_pruned_top_n_into(
                &hm, &idx, top_positions, max_candidates, &probs, &[],
                10, &mut scratch, &mut got);
            assert!(st.pruned);
            if !st.fallback {
                assert!(st.scored < d, "non-fallback must prune");
                pruned_for_real += 1;
            }
            total_recall += recall(&want, &got);
        }
        let mean = total_recall / n_requests as f64;
        assert!(mean >= RECALL_BOUND,
                "d={d} m={m} k={k}: recall {mean:.4} < {RECALL_BOUND}");
    }
    assert!(pruned_for_real > 0,
            "at least one shape must exercise the non-fallback path");
}

#[test]
fn pruned_scores_are_bitwise_equal_to_the_full_sweep() {
    // unstructured probabilities: recall is not the point here, the
    // bitwise-rescore contract is — every returned score must equal
    // the exhaustive score of that item to the bit
    let hm = HashMatrix::random(3000, 300, 4, &mut Rng::new(21));
    let idx = PositionIndex::build(&hm);
    let mut rng = Rng::new(22);
    let probs: Vec<f32> = (0..hm.m).map(|_| rng.f32() + 1e-3).collect();
    let full = decode_scores(&probs, &hm);
    let mut scratch = DecodeScratch::new();
    let mut got = Vec::new();
    let st = decode_pruned_top_n_into(&hm, &idx, 24, 2000, &probs, &[],
                                      10, &mut scratch, &mut got);
    assert!(st.pruned && !st.fallback);
    assert!(st.scored < hm.d);
    assert_eq!(got.len(), 10);
    for &(item, score) in &got {
        assert_eq!(score.to_bits(), full[item].to_bits(),
                   "item {item}: pruned rescore must be bitwise exact");
    }
}

#[test]
fn decode_top_n_into_is_correct_through_dirty_scratch() {
    let hm = HashMatrix::random(600, 80, 3, &mut Rng::new(31));
    let mut rng = Rng::new(32);
    let be = Bloom::new(hm, None).with_decode(DecodeStrategy::Pruned {
        top_positions: 48,
        max_candidates: 580,
    });
    let mut scratch = DecodeScratch {
        logs: vec![7.0; 999],
        scores: vec![-3.0; 5],
        cands: vec![1, 1, 2],
        cand_scores: vec![0.25; 17],
        heap: vec![(4.5, 123); 31],
    };
    for round in 0..3 {
        let probs = structured_probs(&be.hm_in, 16, &mut rng);
        let mut fresh = DecodeScratch::new();
        let (mut want, mut got) = (Vec::new(), Vec::new());
        be.decode_top_n_into(&probs, &[2], 10, None, &mut fresh,
                             &mut want);
        let st = be.decode_top_n_into(&probs, &[2], 10, None,
                                      &mut scratch, &mut got);
        assert!(st.pruned);
        assert_eq!(got, want, "round {round}: dirty scratch leaked");
        // and the per-call strategy override through the same scratch
        be.decode_top_n_into(&probs, &[2],
                             10, Some(DecodeStrategy::Exhaustive),
                             &mut fresh, &mut want);
        let st = be.decode_top_n_into(&probs, &[2], 10,
                                      Some(DecodeStrategy::Exhaustive),
                                      &mut scratch, &mut got);
        assert!(!st.pruned);
        assert_eq!(st.scored, 600);
        assert_eq!(got, want, "round {round}: exhaustive via scratch");
    }
}

/// Catalog-scale smoke (CI runs it with `--release -- --ignored`): a
/// million-item Zipf catalog, m = d/10, served through the pruned
/// tier. Asserts the acceptance contract end to end — recall@10 >=
/// [`RECALL_BOUND`] vs the exhaustive oracle, no fallback, and a
/// candidate set under a tenth of the catalog.
#[test]
#[ignore = "catalog-scale (needs --release); CI runs it explicitly"]
fn catalog_scale_smoke() {
    let (d, m, k) = (1_000_000usize, 100_000usize, 4usize);
    let mut rng = Rng::new(43);
    let hm = HashMatrix::random(d, m, k, &mut rng);
    let idx = PositionIndex::build_parallel(&hm);
    let zipf = ZipfStream::new(d, 1.05);
    let mut scratch = DecodeScratch::new();
    let (mut want, mut got) = (Vec::new(), Vec::new());
    let mut total_recall = 0.0f64;
    let n_requests = 8usize;
    for _ in 0..n_requests {
        let mut probs: Vec<f32> =
            (0..m).map(|_| rng.f32() * 0.01 + 1e-4).collect();
        let mut boosted: Vec<usize> = Vec::with_capacity(16);
        while boosted.len() < 16 {
            let item = zipf.sample(&mut rng);
            if boosted.contains(&item) {
                continue;
            }
            boosted.push(item);
            for &p in hm.row(item) {
                probs[p as usize] = 0.5 + rng.f32() * 0.5;
            }
        }
        decode_exhaustive_top_n_into(&hm, &probs, &[], 10,
                                     &mut scratch, &mut want);
        let st = decode_pruned_top_n_into(&hm, &idx, 128, 65_536,
                                          &probs, &[], 10,
                                          &mut scratch, &mut got);
        assert!(st.pruned && !st.fallback,
                "million-item pruned decode must not fall back");
        assert!(st.scored < d / 10,
                "candidate set {} is not sublinear in d", st.scored);
        total_recall += recall(&want, &got);
    }
    let mean = total_recall / n_requests as f64;
    assert!(mean >= RECALL_BOUND,
            "catalog-scale recall@10 {mean:.4} < {RECALL_BOUND}");
}
