//! Property-based tests over the library's core invariants, using the
//! in-repo shrinking harness (`util::proptest`). Seeds are fixed so
//! failures are reproducible; every property prints its minimal
//! counter-example on failure.

use bloomrec::bloom::{cbe_rewrite, decode_scores, BloomEncoder, HashMatrix};
use bloomrec::linalg::knn::{argsort_desc, top_k};
use bloomrec::linalg::sparse::Csr;
use bloomrec::util::proptest::check;
use bloomrec::util::rng::Rng;
use bloomrec::util::stats::mann_whitney_u;

#[test]
fn prop_hash_matrix_rows_always_distinct() {
    check("hash-rows-distinct", 0xA1, 40,
          |rng| {
              let m = 2 + rng.below(64);
              let k = 1 + rng.below(m.min(10));
              let d = 1 + rng.below(200);
              (d, m, k)
          },
          |&(d, m, k)| {
              let mut rng = Rng::new(d as u64 * 31 + m as u64);
              let hm = HashMatrix::random(d, m, k, &mut rng);
              for i in 0..d {
                  let set: std::collections::HashSet<_> =
                      hm.row(i).iter().collect();
                  if set.len() != k {
                      return Err(format!("row {i} has dup: {:?}",
                                         hm.row(i)));
                  }
                  if hm.row(i).iter().any(|&p| p as usize >= m) {
                      return Err(format!("row {i} out of range"));
                  }
              }
              Ok(())
          });
}

#[test]
fn prop_encode_has_no_false_negatives() {
    check("no-false-negatives", 0xA2, 40,
          |rng| {
              let m = 8 + rng.below(64);
              let k = 1 + rng.below(6.min(m));
              let d = 20 + rng.below(300);
              let c = 1 + rng.below(15);
              let seed = rng.next_u64();
              (vec![d, m, k, c], seed)
          },
          |input| {
              let (dims, seed) = input;
              let (d, m, k, c) = (dims[0], dims[1], dims[2], dims[3]);
              if k > m || c > d {
                  return Ok(());
              }
              let mut rng = Rng::new(*seed);
              let hm = HashMatrix::random(d, m, k, &mut rng);
              let enc = BloomEncoder::new(&hm);
              let items: Vec<u32> = rng.sample_distinct(d, c)
                  .into_iter().map(|i| i as u32).collect();
              let mut u = vec![0.0; m];
              enc.encode_into(&items, &mut u);
              for &it in &items {
                  if !enc.contains(&u, it) {
                      return Err(format!("false negative for {it}"));
                  }
              }
              Ok(())
          });
}

#[test]
fn prop_decode_ranks_encoded_set_first_when_superset_distinct() {
    // for any encoded set, items whose probes are all inside the active
    // bit set must outrank items probing at least one zero bit
    check("decode-veto-order", 0xA3, 30,
          |rng| rng.next_u64(),
          |&seed| {
              let mut rng = Rng::new(seed);
              let d = 50 + rng.below(200);
              let m = 24 + rng.below(64);
              let k = 2 + rng.below(4);
              let hm = HashMatrix::random(d, m, k, &mut rng);
              let enc = BloomEncoder::new(&hm);
              let c = 1 + rng.below(4);
              let items: Vec<u32> = rng.sample_distinct(d, c)
                  .into_iter().map(|i| i as u32).collect();
              let mut u = vec![0.0f32; m];
              enc.encode_into(&items, &mut u);
              let total: f32 = u.iter().sum();
              let probs: Vec<f32> = u.iter()
                  .map(|&v| (v + 1e-9) / (total + m as f32 * 1e-9))
                  .collect();
              let scores = decode_scores(&probs, &hm);
              let member_min = items.iter()
                  .map(|&i| scores[i as usize])
                  .fold(f32::INFINITY, f32::min);
              for i in 0..d {
                  let is_member = enc.contains(&u, i as u32);
                  if !is_member && scores[i] >= member_min {
                      return Err(format!(
                          "non-member {i} ({}) outranks a member ({})",
                          scores[i], member_min));
                  }
              }
              Ok(())
          });
}

#[test]
fn prop_cbe_preserves_row_distinctness() {
    check("cbe-distinct", 0xA4, 25,
          |rng| rng.next_u64(),
          |&seed| {
              let mut rng = Rng::new(seed);
              let d = 16 + rng.below(64);
              let k = 2 + rng.below(3);
              let m = (2 * k + 2) + rng.below(32);
              let hm0 = HashMatrix::random(d, m, k, &mut rng);
              // random sparse instance matrix
              let n = 30 + rng.below(100);
              let rows: Vec<Vec<u32>> = (0..n)
                  .map(|_| {
                      let c = 1 + rng.below(4);
                      rng.sample_distinct(d, c.min(d))
                          .into_iter().map(|i| i as u32).collect()
                  })
                  .collect();
              let x = Csr::from_row_sets(d, &rows);
              let mut hm = hm0;
              cbe_rewrite(&mut hm, &x, &mut rng);
              for i in 0..d {
                  let set: std::collections::HashSet<_> =
                      hm.row(i).iter().collect();
                  if set.len() != k {
                      return Err(format!("row {i}: {:?}", hm.row(i)));
                  }
              }
              Ok(())
          });
}

#[test]
fn prop_top_k_is_argsort_prefix() {
    check("topk-prefix", 0xA5, 60,
          |rng| {
              let n = 1 + rng.below(300);
              let scores: Vec<f64> = (0..n)
                  .map(|_| (rng.below(50) as f64) / 10.0) // many ties
                  .collect();
              let k = rng.below(n + 5);
              (scores, k)
          },
          |(scores, k)| {
              let scores_f32: Vec<f32> =
                  scores.iter().map(|&v| v as f32).collect();
              let full = argsort_desc(&scores_f32);
              let got = top_k(&scores_f32, *k);
              let want = &full[..(*k).min(full.len())];
              if got != want {
                  return Err(format!("k={k}: {got:?} != {want:?}"));
              }
              Ok(())
          });
}

#[test]
fn prop_csr_matvec_matches_dense() {
    check("csr-matvec", 0xA6, 40,
          |rng| rng.next_u64(),
          |&seed| {
              let mut rng = Rng::new(seed);
              let rows = 1 + rng.below(20);
              let cols = 1 + rng.below(20);
              let mut triplets = Vec::new();
              for r in 0..rows {
                  for c in 0..cols {
                      if rng.bool(0.3) {
                          triplets.push((r, c,
                                         (rng.f32() * 4.0) - 2.0));
                      }
                  }
              }
              let m = Csr::from_triplets(rows, cols, triplets);
              let x: Vec<f32> = (0..cols).map(|_| rng.f32()).collect();
              let got = m.matvec(&x);
              let dense = m.to_dense();
              for r in 0..rows {
                  let want: f32 = (0..cols)
                      .map(|c| dense.at(r, c) * x[c])
                      .sum();
                  if (got[r] - want).abs() > 1e-4 {
                      return Err(format!("row {r}: {} vs {want}", got[r]));
                  }
              }
              Ok(())
          });
}

#[test]
fn prop_mwu_p_value_in_unit_range_and_symmetric() {
    check("mwu-sane", 0xA7, 60,
          |rng| {
              let n1 = 2 + rng.below(12);
              let n2 = 2 + rng.below(12);
              let a: Vec<f64> = (0..n1)
                  .map(|_| (rng.below(8) as f64) * 0.5).collect();
              let b: Vec<f64> = (0..n2)
                  .map(|_| (rng.below(8) as f64) * 0.5).collect();
              (a, b)
          },
          |(a, b)| {
              let r1 = mann_whitney_u(a, b);
              let r2 = mann_whitney_u(b, a);
              if !(0.0..=1.0).contains(&r1.p_value) {
                  return Err(format!("p out of range: {}", r1.p_value));
              }
              if (r1.p_value - r2.p_value).abs() > 1e-9 {
                  return Err(format!("asymmetric: {} vs {}",
                                     r1.p_value, r2.p_value));
              }
              Ok(())
          });
}

#[test]
fn prop_json_round_trips_random_values() {
    use bloomrec::util::json::Json;
    fn random_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth > 2 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.bool(0.5)),
            2 => Json::Num((rng.below(2000) as f64 - 1000.0) / 8.0),
            3 => {
                let n = rng.below(8);
                Json::Str((0..n).map(|_| {
                    let chars = ['a', 'ß', '"', '\\', '\n', '7', 'é'];
                    chars[rng.below(chars.len())]
                }).collect())
            }
            4 => Json::Arr((0..rng.below(4))
                .map(|_| random_json(rng, depth + 1)).collect()),
            _ => Json::Obj((0..rng.below(4))
                .map(|i| (format!("k{i}"), random_json(rng, depth + 1)))
                .collect()),
        }
    }
    check("json-roundtrip", 0xA8, 80,
          |rng| rng.next_u64(),
          |&seed| {
              let mut rng = Rng::new(seed);
              let v = random_json(&mut rng, 0);
              let text = v.to_string_pretty();
              match Json::parse(&text) {
                  Ok(back) if back == v => Ok(()),
                  Ok(back) => Err(format!("{v:?} -> {back:?}")),
                  Err(e) => Err(format!("parse failed: {e} on {text}")),
              }
          });
}

#[test]
fn prop_identity_embedding_decode_is_inverse() {
    use bloomrec::embedding::{Embedding, Identity};
    check("identity-inverse", 0xA9, 40,
          |rng| {
              let d = 4 + rng.below(100);
              let c = 1 + rng.below(d.min(10));
              let seed = rng.next_u64();
              (d, c, seed)
          },
          |&(d, c, seed)| {
              let mut rng = Rng::new(seed);
              let e = Identity { d };
              let items: Vec<u32> = rng.sample_distinct(d, c)
                  .into_iter().map(|i| i as u32).collect();
              let mut u = vec![0.0; d];
              e.encode_input(&items, &mut u);
              let scores = e.decode(&u);
              let top = top_k(&scores, c);
              let got: std::collections::HashSet<u32> =
                  top.into_iter().map(|i| i as u32).collect();
              let want: std::collections::HashSet<u32> =
                  items.iter().copied().collect();
              if got != want {
                  return Err(format!("{got:?} != {want:?}"));
              }
              Ok(())
          });
}
