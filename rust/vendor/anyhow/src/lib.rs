//! Offline stand-in for the `anyhow` crate, covering exactly the API
//! subset this workspace uses: [`Error`], [`Result`], the [`anyhow!`],
//! [`bail!`] and [`ensure!`] macros, and the [`Context`] extension trait.
//!
//! Semantics mirror upstream where it matters:
//! * any `std::error::Error` converts into [`Error`] via `?`, capturing
//!   its source chain;
//! * `{e}` displays the outermost message, `{e:#}` the whole chain
//!   joined with `": "`;
//! * [`Error`] deliberately does NOT implement `std::error::Error`, which
//!   is what makes the blanket `From` impl coherent (same trick as
//!   upstream anyhow).

use std::fmt;

/// An error chain: `chain[0]` is the outermost message, later entries are
/// the causes (outside-in).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a displayable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    fn wrap(mut self, context: String) -> Error {
        self.chain.insert(0, context);
        self
    }

    /// The error chain, outermost message first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to an error, like upstream anyhow's `Context`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
        -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().wrap(context.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
        -> Result<T> {
        self.map_err(|e| e.into().wrap(f().to_string()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
        -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/path")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let err = io_fail().unwrap_err();
        assert!(!err.to_string().is_empty());
    }

    #[test]
    fn context_prepends() {
        let err = io_fail().context("loading config").unwrap_err();
        assert_eq!(err.to_string(), "loading config");
        let full = format!("{err:#}");
        assert!(full.starts_with("loading config: "), "{full}");
    }

    #[test]
    fn with_context_is_lazy() {
        fn never() -> String {
            panic!("must not evaluate on Ok")
        }
        let ok = Ok::<u32, std::io::Error>(7).with_context(never);
        assert_eq!(ok.unwrap(), 7);
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative: {x}");
            if x > 100 {
                bail!("too big: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert_eq!(f(-1).unwrap_err().to_string(), "negative: -1");
        assert_eq!(f(101).unwrap_err().to_string(), "too big: 101");
        let e = anyhow!("plain {}", "message");
        assert_eq!(e.to_string(), "plain message");
    }

    #[test]
    fn debug_shows_cause_chain() {
        let err = io_fail().context("outer").unwrap_err();
        let dbg = format!("{err:?}");
        assert!(dbg.contains("outer"));
        assert!(dbg.contains("Caused by:"), "{dbg}");
    }
}
