// Placeholder so `cargo` can resolve the optional `xla` dependency with
// no network access. The real PJRT bindings (the `xla` / xla-rs crate,
// which links libxla) must be provided to actually build with
// `--features xla`: replace the `xla` path dependency in rust/Cargo.toml
// with a checkout of xla-rs (see README.md "Backend feature matrix").
compile_error!(
    "the `xla` feature needs the real xla-rs crate: point the `xla` path \
     dependency in rust/Cargo.toml at an xla-rs checkout (this stub only \
     exists so default builds resolve offline)"
);
