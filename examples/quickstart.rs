//! Quickstart: the Bloom-embedding public API in five minutes.
//!
//!   cargo run --release --example quickstart
//!
//! 1. Build a hash matrix and encode a sparse item set (paper Eq. 1).
//! 2. Recover a ranking from an (artificial) softmax output (Eqs. 2-3).
//! 3. Train a real (tiny) recommender through the AOT artifact and ask it
//!    for recommendations.

use bloomrec::bloom::{decode_top_n, BloomEncoder, HashMatrix};
use bloomrec::coordinator::{self, DatasetCache, Method, RunSpec};
use bloomrec::data::Scale;
use bloomrec::runtime::Runtime;
use bloomrec::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    // --- 1. compress a 10,000-item space into 256 bits -----------------
    let d = 10_000;
    let (m, k) = (256, 4);
    let mut rng = Rng::new(42);
    let hm = HashMatrix::random(d, m, k, &mut rng);
    println!("hash matrix: {} items -> {m} bits via {k} hashes \
              ({} KiB of RAM, no GPU memory)",
             d, hm.bytes() / 1024);

    let enc = BloomEncoder::new(&hm);
    let user_items: Vec<u32> = vec![7, 4242, 9001];
    let mut u = vec![0.0f32; m];
    let active = enc.encode_into(&user_items, &mut u);
    println!("encoded {:?} -> {active} active bits of {m}", user_items);

    // --- 2. decode a model output back to items ------------------------
    // fake a "softmax output" that loves exactly those bits
    let sum: f32 = u.iter().sum();
    let probs: Vec<f32> =
        u.iter().map(|&v| (v + 1e-4) / (sum + m as f32 * 1e-4)).collect();
    let top = decode_top_n(&probs, &hm, 3);
    println!("decoded top-3: {top:?} (the encoded items, recovered)");

    // --- 3. end-to-end with a real artifact -----------------------------
    let rt = Runtime::new(std::path::Path::new("artifacts"))?;
    let spec = RunSpec {
        task: "bc".into(),
        method: Method::Be { k: 4 },
        ratio: 0.2,
        seed: 1,
        scale: Scale::Tiny,
        epochs: Some(4),
    };
    let cache = DatasetCache::new();
    let res = coordinator::run(&rt, &cache, &spec)?;
    println!(
        "\ntrained {} with BE k=4 at m/d=0.2: MAP={:.4} (random={:.4})\n\
         epoch losses: {:?}",
        res.task, res.score, res.random_score,
        res.train.epoch_losses,
    );
    Ok(())
}
