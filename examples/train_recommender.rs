//! End-to-end driver (EXPERIMENTS.md §E2E): train the MSD-analog song
//! recommender with Bloom embeddings through the full three-layer stack —
//! Rust coordinator -> AOT HLO artifact (JAX model + Pallas fused-dense
//! kernel) -> PJRT CPU — and compare against the uncompressed baseline.
//!
//!   cargo run --release --example train_recommender [-- --scale small]
//!
//! Logs the loss curve, reports MAP for BE (m/d = 0.2, k = 4) vs the
//! m = d baseline, and prints the parameter/memory savings.

use bloomrec::config::Options;
use bloomrec::coordinator::{self, DatasetCache, Method, RunSpec};
use bloomrec::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    bloomrec::util::logging::init_from_env();
    let args: Vec<String> = std::env::args().skip(1)
        .filter(|a| a != "--").collect();
    let (opts, _) = Options::parse(&args)?;

    let rt = Runtime::new(&opts.artifact_dir)?;
    let cache = DatasetCache::new();
    let task = "msd";

    println!("=== end-to-end: {task} recommender ===");
    println!("scale={:?} seed={}", opts.scale, opts.seeds[0]);

    // --- baseline: m = d ------------------------------------------------
    let base = coordinator::run(&rt, &cache, &RunSpec {
        task: task.into(),
        method: Method::Baseline,
        ratio: 1.0,
        seed: opts.seeds[0],
        scale: opts.scale,
        epochs: opts.epochs,
    })?;
    println!("\n[baseline m=d={}] weights={}  train={:.1}s",
             base.d, base.n_weights, base.train.train_secs);
    print_loss_curve("baseline", &base.train.first_epoch_curve);
    println!("epoch losses: {:?}", rounded(&base.train.epoch_losses));
    println!("MAP = {:.4}   (random = {:.4})", base.score,
             base.random_score);

    // --- Bloom embedding at 5x compression -------------------------------
    let be = coordinator::run(&rt, &cache, &RunSpec {
        task: task.into(),
        method: Method::Be { k: 4 },
        ratio: 0.2,
        seed: opts.seeds[0],
        scale: opts.scale,
        epochs: opts.epochs,
    })?;
    println!("\n[BE k=4 m/d=0.2 m={}] weights={}  train={:.1}s",
             be.m, be.n_weights, be.train.train_secs);
    print_loss_curve("bloom", &be.train.first_epoch_curve);
    println!("epoch losses: {:?}", rounded(&be.train.epoch_losses));
    println!("MAP = {:.4}   (random = {:.4})", be.score, be.random_score);

    // --- the paper's headline numbers ------------------------------------
    println!("\n=== summary ===");
    println!("score ratio   S_be/S_0 = {:.3}",
             be.score / base.score.max(1e-12));
    println!("param ratio   {:.3} ({} -> {} weights)",
             be.n_weights as f64 / base.n_weights as f64,
             base.n_weights, be.n_weights);
    println!("train ratio   T_be/T_0 = {:.3} ({:.1}s -> {:.1}s)",
             be.train.train_secs / base.train.train_secs.max(1e-9),
             base.train.train_secs, be.train.train_secs);
    println!("eval  ratio   {:.3} ({:.2}s -> {:.2}s; includes decode)",
             be.eval.eval_secs / base.eval.eval_secs.max(1e-9),
             base.eval.eval_secs, be.eval.eval_secs);
    Ok(())
}

fn rounded(xs: &[f64]) -> Vec<f64> {
    xs.iter().map(|x| (x * 1000.0).round() / 1000.0).collect()
}

/// ASCII loss curve over the first epoch (bucketed to 60 columns).
fn print_loss_curve(label: &str, curve: &[f32]) {
    if curve.is_empty() {
        return;
    }
    let cols = 60usize.min(curve.len());
    let bucket = curve.len().div_ceil(cols);
    let buckets: Vec<f32> = curve
        .chunks(bucket)
        .map(|c| c.iter().sum::<f32>() / c.len() as f32)
        .collect();
    let max = buckets.iter().cloned().fold(f32::MIN, f32::max);
    let min = buckets.iter().cloned().fold(f32::MAX, f32::min);
    let rows = 8;
    println!("first-epoch loss curve ({label}): {min:.3}..{max:.3}");
    for r in (0..rows).rev() {
        let lo = min + (max - min) * r as f32 / rows as f32;
        let line: String = buckets
            .iter()
            .map(|&b| if b >= lo { '█' } else { ' ' })
            .collect();
        println!("  {line}");
    }
}
