//! Serving example: train a compressed recommender, then serve batched
//! recommendation requests through the dynamic batcher and report
//! latency/throughput — the deployment scenario the paper's introduction
//! motivates (limited-hardware serving).
//!
//!   cargo run --release --example serve_recommendations

use std::sync::Arc;

use bloomrec::config::Options;
use bloomrec::coordinator::{self, DatasetCache, Method, RunSpec};
use bloomrec::runtime::Runtime;
use bloomrec::serve::{BatcherConfig, RecRequest, ServeConfig, Server};

fn main() -> anyhow::Result<()> {
    bloomrec::util::logging::init_from_env();
    let args: Vec<String> = std::env::args().skip(1)
        .filter(|a| a != "--").collect();
    let (opts, _) = Options::parse(&args)?;

    let rt = Arc::new(Runtime::new(&opts.artifact_dir)?);
    let cache = DatasetCache::new();
    let task = rt.manifest.task("ml")?.clone();
    let (ratio, k) = (0.2, 4);
    let m = bloomrec::runtime::round_m(task.d, ratio);

    // train
    println!("training ml recommender (m/d={ratio}, k={k})...");
    let spec = RunSpec {
        task: task.name.clone(),
        method: Method::Be { k },
        ratio,
        seed: opts.seeds[0],
        scale: opts.scale,
        epochs: opts.epochs,
    };
    let ds = cache.get(&task, opts.scale, opts.seeds[0]);
    let emb: Arc<dyn bloomrec::embedding::Embedding> =
        coordinator::build_embedding(spec.method, &ds, &task, m, spec.seed)?
            .into();
    let train_spec =
        rt.manifest.find(&task.name, "train", "softmax_ce", m)?.clone();
    let predict_spec =
        rt.manifest.find(&task.name, "predict", "softmax_ce", m)?.clone();
    let (state, report) = coordinator::train(
        &rt, &train_spec, &ds, emb.as_ref(),
        &coordinator::TrainConfig {
            epochs: opts.epochs.unwrap_or(task.epochs),
            seed: spec.seed,
            verbose: true,
            shards: 0,
        })?;
    println!("trained: {} steps in {:.1}s", report.steps,
             report.train_secs);

    // serve under three batching policies to show the trade-off
    for (label, batcher) in [
        ("batch=1 (no batching)", BatcherConfig {
            max_batch: 1,
            max_wait: std::time::Duration::from_micros(1),
        }),
        ("batch<=16, wait<=1ms", BatcherConfig {
            max_batch: 16,
            max_wait: std::time::Duration::from_millis(1),
        }),
        ("batch<=64, wait<=2ms", BatcherConfig {
            max_batch: 64,
            max_wait: std::time::Duration::from_millis(2),
        }),
    ] {
        let server = Server::start(
            Arc::clone(&rt), predict_spec.clone(), state.clone(),
            Arc::clone(&emb),
            ServeConfig { replicas: 2, batcher,
                          ..ServeConfig::default() })?;

        let n_requests = 3000;
        let mut pending = Vec::new();
        for i in 0..n_requests {
            let ex = &ds.test[i % ds.test.len()];
            pending.push(server.submit(RecRequest::new(
                ex.input_items().to_vec(), opts.top_n)));
            // a little client-side pipelining
            if pending.len() >= 512 {
                for rx in pending.drain(..256) {
                    rx.recv()?;
                }
            }
        }
        for rx in pending {
            rx.recv()?;
        }
        let s = server.metrics.snapshot();
        println!(
            "[{label:22}] {:>6.0} req/s  fill={:.2}  \
             p50={:.2}ms p95={:.2}ms p99={:.2}ms",
            s.throughput_rps, s.mean_batch_fill, s.p50_ms, s.p95_ms,
            s.p99_ms
        );
        server.shutdown();
    }

    // show one actual recommendation
    let server = Server::start(Arc::clone(&rt), predict_spec, state, emb,
                               ServeConfig::default())?;
    let ex = &ds.test[0];
    let resp = server.recommend(RecRequest::new(
        ex.input_items().to_vec(), 5));
    println!("\nsample request items={:?}", ex.input_items());
    println!("recommended: {:?}", resp.items);
    println!("ground-truth future items: {:?}", ex.target_items());
    server.shutdown();
    Ok(())
}
