//! Compression sweep (a single-task slice of Fig. 1 + Fig. 3): score and
//! time ratios across m/d for one task.
//!
//!   cargo run --release --example compression_sweep [-- --tasks bc]

use bloomrec::config::Options;
use bloomrec::coordinator::{self, DatasetCache, Method, RunSpec};
use bloomrec::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    bloomrec::util::logging::init_from_env();
    let args: Vec<String> = std::env::args().skip(1)
        .filter(|a| a != "--").collect();
    let (opts, _) = Options::parse(&args)?;
    let task_name = opts
        .tasks
        .as_ref()
        .and_then(|t| t.first().cloned())
        .unwrap_or_else(|| "bc".to_string());

    let rt = Runtime::new(&opts.artifact_dir)?;
    let cache = DatasetCache::new();
    let task = rt.manifest.task(&task_name)?.clone();

    let base = coordinator::run(&rt, &cache, &RunSpec {
        task: task.name.clone(),
        method: Method::Baseline,
        ratio: 1.0,
        seed: opts.seeds[0],
        scale: opts.scale,
        epochs: opts.epochs,
    })?;
    println!("task={} d={} baseline score={:.4} train={:.1}s",
             task.name, task.d, base.score, base.train.train_secs);
    println!("\n{:>6} {:>6} {:>9} {:>9} {:>12} {:>11}",
             "m/d", "m", "S_i/S_0", "T_i/T_0", "eval ratio", "weights");

    for &ratio in &task.ratios {
        let r = coordinator::run(&rt, &cache, &RunSpec {
            task: task.name.clone(),
            method: Method::Be { k: 4 },
            ratio,
            seed: opts.seeds[0],
            scale: opts.scale,
            epochs: opts.epochs,
        })?;
        println!("{:>6.2} {:>6} {:>9.3} {:>9.3} {:>12.3} {:>11}",
                 ratio, r.m,
                 r.score / base.score.max(1e-12),
                 r.train.train_secs / base.train.train_secs.max(1e-9),
                 r.eval.eval_secs / base.eval.eval_secs.max(1e-9),
                 r.n_weights);
    }
    Ok(())
}
