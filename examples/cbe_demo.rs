//! CBE demo (paper Sec. 6): show Algorithm 1 redirecting collisions onto
//! co-occurring item pairs, then compare BE vs CBE scores on one task.
//!
//!   cargo run --release --example cbe_demo

use bloomrec::bloom::{cbe_rewrite, cooccurrence_stats, HashMatrix};
use bloomrec::config::Options;
use bloomrec::coordinator::{self, DatasetCache, Method, RunSpec};
use bloomrec::runtime::Runtime;
use bloomrec::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    bloomrec::util::logging::init_from_env();
    let args: Vec<String> = std::env::args().skip(1)
        .filter(|a| a != "--").collect();
    let (opts, _) = Options::parse(&args)?;

    // --- mechanics on a toy dataset -------------------------------------
    let rt = Runtime::new(&opts.artifact_dir)?;
    let cache = DatasetCache::new();
    let task = rt.manifest.task("amz")?.clone();
    let ds = cache.get(&task, opts.scale, opts.seeds[0]);
    let x = ds.train_input_csr();
    let st = cooccurrence_stats(&x);
    println!("amz-analog input co-occurrence: {:.2}% of pairs, rho={:.1e}",
             st.pct_pairs, st.rho);

    let m = bloomrec::runtime::round_m(task.d, 0.2);
    let mut rng = Rng::new(7);
    let mut hm = HashMatrix::random(task.d, m, 4, &mut rng);
    let before = hm.h.clone();
    let redirected = cbe_rewrite(&mut hm, &x, &mut rng);
    let changed = before.iter().zip(&hm.h).filter(|(a, b)| a != b).count();
    println!("Algorithm 1: {redirected} pairs redirected, \
              {changed}/{} projections rewritten", hm.h.len());

    // verify: the heaviest co-occurring pair now shares a bit
    let pairs = x.cooccurrence_pairs();
    if let Some((&(a, b), cnt)) =
        pairs.iter().max_by(|x, y| x.1.partial_cmp(y.1).unwrap())
    {
        let sa: std::collections::HashSet<_> =
            hm.row(a as usize).iter().collect();
        let shared =
            hm.row(b as usize).iter().filter(|p| sa.contains(p)).count();
        println!("heaviest pair ({a},{b}) co-occurs {cnt}x -> \
                  shares {shared} bit(s)");
    }

    // --- score comparison ------------------------------------------------
    println!("\nBE vs CBE on amz at the Table-5 test points:");
    for ratio in task.test_points.clone() {
        let be = coordinator::run(&rt, &cache, &RunSpec {
            task: task.name.clone(),
            method: Method::Be { k: 4 },
            ratio,
            seed: opts.seeds[0],
            scale: opts.scale,
            epochs: opts.epochs,
        })?;
        let cbe = coordinator::run(&rt, &cache, &RunSpec {
            task: task.name.clone(),
            method: Method::Cbe { k: 4 },
            ratio,
            seed: opts.seeds[0],
            scale: opts.scale,
            epochs: opts.epochs,
        })?;
        println!("  m/d={ratio:4}: BE MAP={:.4}  CBE MAP={:.4}  \
                  (delta {:+.4})",
                 be.score, cbe.score, cbe.score - be.score);
    }
    Ok(())
}
