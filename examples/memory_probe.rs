//! Memory-regression diagnostic: run thousands of PJRT train steps and
//! assert RSS stays flat.
//!
//!   cargo run --release --example memory_probe
//!
//! Guards against the upstream `xla` 0.1.6 bug this repo works around:
//! the crate's literal-based `execute` leaks every input device buffer
//! (`buffer.release()` with no free in the C++ shim), which OOM-killed
//! multi-hour experiment sweeps. `runtime::Executable::run` therefore
//! uploads Rust-owned buffers and calls `execute_b`; this probe fails
//! loudly if that regresses.

use bloomrec::model::ModelState;
use bloomrec::runtime::{Execution, HostTensor, Runtime};
use bloomrec::util::rng::Rng;

fn rss_gb() -> f64 {
    let s = std::fs::read_to_string("/proc/self/status").unwrap_or_default();
    for line in s.lines() {
        if let Some(kb) = line.strip_prefix("VmRSS:") {
            let kb: f64 = kb.trim().trim_end_matches(" kB").trim()
                .parse().unwrap_or(0.0);
            return kb / 1048576.0;
        }
    }
    0.0
}

fn main() -> anyhow::Result<()> {
    let rt = Runtime::new(std::path::Path::new("artifacts"))?;
    let spec = rt.manifest
        .find("ml", "train", "softmax_ce", 152)?.clone();
    let exe = rt.load(&spec.name)?;
    let mut rng = Rng::new(1);
    let mut st = ModelState::init(&spec, &mut rng);
    let x = HostTensor::zeros(&spec.x_shape());
    let y = HostTensor::zeros(&spec.y_shape());

    let mut baseline = 0.0;
    let steps = 2000;
    for i in 0..steps {
        let mut inputs: Vec<&HostTensor> = Vec::new();
        inputs.extend(st.params.iter());
        inputs.extend(st.opt_state.iter());
        inputs.push(&x);
        inputs.push(&y);
        let mut out = exe.run(&inputs, &[])?;
        out.pop();
        let opt = out.split_off(st.params.len());
        st.params = out;
        st.opt_state = opt;
        if i == 100 {
            baseline = rss_gb(); // after warmup/arena growth
        }
        if i % 400 == 0 {
            println!("step {i:5}: rss={:.3} GB", rss_gb());
        }
    }
    let end = rss_gb();
    println!("end:        rss={end:.3} GB (post-warmup baseline {baseline:.3})");
    let grown = end - baseline;
    if grown > 0.2 {
        anyhow::bail!(
            "memory leak detected: RSS grew {grown:.2} GB over \
             {steps} steps — did Executable::run regress to execute()?");
    }
    println!("OK: no per-step leak ({grown:+.3} GB over {steps} steps)");
    Ok(())
}
